//! The continuous-batching generation engine (vLLM's idea at this
//! system's scale): instead of running each `generate` request's decode
//! loop alone at M=1 on the executor thread, active sequences share one
//! batched transformer step per token — late-arriving requests join the
//! running batch at *step* granularity instead of waiting for earlier
//! generations to finish.
//!
//! Three pieces:
//!
//! * [`KvPool`] — a bounded arena of preallocated per-layer K/V slots
//!   ([`DecodeState`]s), leased to sequences and reset on release, with
//!   `memory_bytes()` accounting. Replaces the one-fresh-allocation-per-
//!   request behaviour of the serial path and bounds decode memory.
//! * the sequence manager — admission queue (`waiting`) plus the active
//!   set: prompt-prefill pending → decoding → finished, with admission
//!   control that queues when the pool is exhausted and rejects with a
//!   structured error when the queue itself is full.
//! * the step loop ([`Engine::tick`]) — admits what fits, then stacks all
//!   active sequences' next tokens into one M=N matrix per scheme group
//!   and drives `forward_step_batched` (native or true-integer), sampling
//!   one token per sequence per step and streaming it to the client.
//!
//! Bit-exactness contract: a sequence decoded by the engine produces
//! exactly the tokens `generate_greedy` would have produced alone, for
//! every served scheme — the batched step applies activation-site
//! transforms per row and all shared math is per-row deterministic (see
//! `model::block::forward_step_batched`). Pinned by rust/tests/engine.rs.
//!
//! The engine is owned and ticked by the coordinator's executor thread
//! (models are not Sync); [`EngineModels`] is the narrow accessor the
//! executor exposes for model lookup/calibration.

use std::collections::VecDeque;
use std::sync::atomic::AtomicBool;
use std::sync::atomic::Ordering::Relaxed;
use std::sync::mpsc::{Sender, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use super::metrics::Metrics;
use super::scheduler::{EvalResponse, SchemeSite};
use super::{ActScheme, SchemeKey};
use crate::model::block::{self, DecodeState};
use crate::model::{ActSite, ModelConfig, NativeModel, QuantizedModel};
use crate::obs::{self, Span, SpanKind};
use crate::quant::gemm::{gemm_timing_enable, gemm_timing_take};
use crate::quant::registry::StaticSpec;
use crate::tensor::Matrix;

/// One streamed decode event: sequence `seq` produced `token`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GenEvent {
    pub seq: u64,
    pub token: u32,
}

/// Engine knobs, surfaced as `repro serve --max-active-seqs` /
/// `--kv-pool-mb` / `--admission-queue`.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Upper bound on concurrently decoding sequences (the step-batch M).
    pub max_active_seqs: usize,
    /// Byte budget for the KV arena; the pool holds
    /// `min(max_active_seqs, budget / slot_bytes)` slots (at least one).
    /// `None` sizes the pool to `max_active_seqs` slots.
    pub kv_pool_bytes: Option<usize>,
    /// Admission-queue bound: sequences waiting for a KV slot beyond this
    /// are shed lowest-priority-first with a structured retryable error
    /// instead of queueing unbounded. Clamped to ≥ 1 — every submission
    /// passes through the queue on its way to a slot, so a zero-length
    /// queue could admit nothing.
    pub max_waiting: usize,
    /// Prefill/decode fairness: at most this many admissions (prefills
    /// run inside admission) per tick, so a deep queue of long prompts
    /// can't starve the active set's decode steps during overload.
    /// Clamped to ≥ 1.
    pub max_prefills_per_tick: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_active_seqs: 32,
            kv_pool_bytes: None,
            max_waiting: 256,
            max_prefills_per_tick: 4,
        }
    }
}

/// A bounded arena of preallocated KV-cache slots. Leasing pops a slot
/// (reset to an empty prefix); releasing returns it. All slots are
/// allocated up front, so `memory_bytes()` is both the current and the
/// peak footprint of engine decode state.
pub struct KvPool {
    free: Vec<DecodeState>,
    slots: usize,
    slot_bytes: usize,
}

impl KvPool {
    pub fn new(slots: usize, model: ModelConfig) -> KvPool {
        assert!(slots >= 1, "a KV pool needs at least one slot");
        let free: Vec<DecodeState> = (0..slots)
            .map(|_| DecodeState::new(model.n_layers, model.seq_len, model.d_model))
            .collect();
        let slot_bytes = free[0].memory_bytes();
        KvPool { free, slots, slot_bytes }
    }

    /// Pool sized from an [`EngineConfig`]: `max_active_seqs` slots,
    /// shrunk to fit the byte budget (clamped to one slot — a pool that
    /// can serve nothing would deadlock admission).
    pub fn with_config(cfg: &EngineConfig, model: ModelConfig) -> KvPool {
        let slot_bytes =
            DecodeState::memory_bytes_for(model.n_layers, model.seq_len, model.d_model);
        let by_budget = cfg
            .kv_pool_bytes
            .map(|b| (b / slot_bytes.max(1)).max(1))
            .unwrap_or(usize::MAX);
        KvPool::new(cfg.max_active_seqs.max(1).min(by_budget), model)
    }

    /// Lease a slot, reset to an empty prefix. `None` when exhausted —
    /// the caller queues or rejects.
    pub fn lease(&mut self) -> Option<DecodeState> {
        self.free.pop().map(|mut s| {
            s.reset();
            s
        })
    }

    /// Return a slot to the pool.
    pub fn release(&mut self, state: DecodeState) {
        debug_assert!(self.free.len() < self.slots, "released more slots than exist");
        self.free.push(state);
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    pub fn in_use(&self) -> usize {
        self.slots - self.free.len()
    }

    /// Bytes of one slot (one sequence's full-stack KV capacity).
    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    /// Total arena bytes (allocation is up-front, so also the peak).
    pub fn memory_bytes(&self) -> usize {
        self.slots * self.slot_bytes
    }
}

/// What the executor hands the engine for one generation request.
pub(crate) struct GenRequest {
    pub tokens: Vec<u32>,
    pub scheme: ActScheme,
    pub key: SchemeKey,
    pub max_new: usize,
    pub resp: SyncSender<Result<EvalResponse>>,
    pub events: Option<Sender<GenEvent>>,
    /// Set when the client disconnects; the engine reaps the sequence at
    /// the next tick and releases its KV slot.
    pub cancel: Arc<AtomicBool>,
    pub submitted: Instant,
    /// Request trace id (0 = untraced). Traced sequences emit queue-wait,
    /// admission, prefill, and per-token decode spans into the span ring.
    pub trace: u64,
    /// Priority class (0 = best-effort … 3 = interactive). Admission
    /// prefers higher classes; shedding victimizes lower classes first.
    pub priority: u8,
}

/// Per-sequence activation-site state: native schemes carry their own
/// [`SchemeSite`] (so aux accounting and batch-coupled scale fields stay
/// per-sequence); the integer static path quantizes inside its GEMMs.
enum SeqSite {
    Native(SchemeSite),
    Integer,
}

/// One decoding sequence (prefill already done).
struct GenSeq {
    id: u64,
    scheme: ActScheme,
    key: SchemeKey,
    max_new: usize,
    generated: Vec<u32>,
    state: DecodeState,
    site: SeqSite,
    /// Last sampled token — the input to the next batched step.
    next: u32,
    resp: SyncSender<Result<EvalResponse>>,
    events: Option<Sender<GenEvent>>,
    cancel: Arc<AtomicBool>,
    submitted: Instant,
    trace: u64,
    /// When the previous token was streamed — the anchor for inter-token
    /// latency and per-token decode spans.
    last_token_at: Instant,
}

/// Narrow model accessor the executor exposes to the engine (lazy
/// construction + static-scale calibration live behind it).
pub(crate) trait EngineModels {
    fn native_model(&mut self, weight_set: &str) -> Result<&NativeModel>;
    fn static_model(&mut self, weight_set: &str, spec: &StaticSpec) -> Result<&QuantizedModel>;
}

pub(crate) struct Engine {
    cfg: EngineConfig,
    pool: KvPool,
    /// Admission queue; each entry keeps its enqueue time so admission
    /// wait is measurable per request.
    waiting: VecDeque<(Instant, GenRequest)>,
    active: Vec<GenSeq>,
    next_id: u64,
    metrics: Arc<Metrics>,
    /// Burn-rate shedding latch: true while the SLO report says both a
    /// fast and the slow window are burning past threshold. Re-evaluated
    /// at most once per second (the windows only move at second
    /// granularity, and evaluation merges rolling slots).
    shed_mode: bool,
    slo_checked_at: Option<u64>,
}

impl Engine {
    pub(crate) fn new(mut cfg: EngineConfig, model: ModelConfig, metrics: Arc<Metrics>) -> Engine {
        cfg.max_waiting = cfg.max_waiting.max(1);
        cfg.max_prefills_per_tick = cfg.max_prefills_per_tick.max(1);
        let pool = KvPool::with_config(&cfg, model);
        metrics.kv_pool_slots.store(pool.slots() as u64, Relaxed);
        metrics.kv_pool_slot_bytes.store(pool.slot_bytes() as u64, Relaxed);
        Engine {
            cfg,
            pool,
            waiting: VecDeque::new(),
            active: Vec::new(),
            next_id: 0,
            metrics,
            shed_mode: false,
            slo_checked_at: None,
        }
    }

    /// No admitted or waiting work — the executor may block for requests.
    pub(crate) fn is_idle(&self) -> bool {
        self.active.is_empty() && self.waiting.is_empty()
    }

    /// Enqueue a generation request. Admission control replaces the old
    /// blind FIFO reject with telemetry-driven, lowest-priority-first
    /// shedding (never a panic, never unbounded memory):
    ///
    /// * While SLO burn-rate shedding is active ([`Self::shed_mode`]),
    ///   best-effort (priority 0) requests are shed immediately — the
    ///   engine stops accepting deferrable load before the queue fills.
    /// * When the queue is full, the lowest-priority entry among the
    ///   queue and the incoming request is shed: an incoming request
    ///   that outranks the worst queued one evicts it and takes its
    ///   place; otherwise the incoming request is shed. Within a class
    ///   the youngest entry is the victim (the oldest has waited
    ///   longest and is closest to service).
    ///
    /// Every shed is a structured retryable error and counts against
    /// its priority class in `shed_pN`.
    pub(crate) fn submit(&mut self, req: GenRequest) {
        if self.shed_mode && req.priority == 0 {
            self.shed(
                req,
                "request shed (priority 0): SLO burn rate over threshold, load shedding active"
                    .to_string(),
            );
            return;
        }
        if self.waiting.len() >= self.cfg.max_waiting {
            let victim_idx = self
                .waiting
                .iter()
                .enumerate()
                .min_by_key(|(i, (_, r))| (r.priority, std::cmp::Reverse(*i)))
                .map(|(i, _)| i);
            match victim_idx {
                Some(idx) if self.waiting[idx].1.priority < req.priority => {
                    let (_, victim) = self.waiting.remove(idx).expect("index from enumerate");
                    let why = format!(
                        "request shed (priority {}): engine at capacity, {} sequences active, \
                         admission queue full ({})",
                        victim.priority,
                        self.active.len(),
                        self.cfg.max_waiting
                    );
                    self.shed(victim, why);
                    // fall through: the incoming request takes the slot
                }
                _ => {
                    let why = format!(
                        "request shed (priority {}): engine at capacity, {} sequences active, \
                         admission queue full ({})",
                        req.priority,
                        self.active.len(),
                        self.cfg.max_waiting
                    );
                    self.shed(req, why);
                    return;
                }
            }
        }
        let wait_us = req.submitted.elapsed().as_micros() as u64;
        self.metrics.queue_wait.record_us(wait_us);
        if req.trace != 0 {
            self.metrics.spans.record(Span {
                trace: req.trace,
                kind: SpanKind::QueueWait,
                start_us: obs::now_us().saturating_sub(wait_us),
                dur_us: wait_us,
                aux: 0,
            });
        }
        self.waiting.push_back((Instant::now(), req));
        self.update_gauges();
    }

    /// Shed one request: structured retryable error, per-priority
    /// accounting, and the same rejected/failed counters the old blind
    /// reject bumped.
    fn shed(&mut self, req: GenRequest, why: String) {
        self.metrics.engine_rejected.fetch_add(1, Relaxed);
        self.metrics.mark_failed();
        self.metrics.mark_shed(req.priority);
        let _ = req.resp.send(Err(anyhow!(why)));
    }

    /// Re-evaluate the SLO burn report at most once per second — the
    /// rolling windows only move at second granularity, and evaluation
    /// merges every live slot.
    fn refresh_shed_mode(&mut self) {
        let now = obs::now_secs();
        if self.slo_checked_at == Some(now) {
            return;
        }
        self.slo_checked_at = Some(now);
        self.shed_mode = self.metrics.slo_report().shedding;
    }

    /// One engine round: admit what fits (prefill runs here), then one
    /// batched decode step per scheme group, then retire finished
    /// sequences. The executor calls this between channel polls, which is
    /// exactly how late arrivals join the running batch.
    pub(crate) fn tick(&mut self, models: &mut dyn EngineModels) {
        self.refresh_shed_mode();
        self.reap_cancelled();
        self.admit(models);
        self.step(models);
        self.update_gauges();
    }

    /// Retire sequences whose client disconnected: queued requests never
    /// admit, active sequences release their KV slot immediately instead
    /// of decoding the rest of `max_new_tokens` into a closed socket.
    fn reap_cancelled(&mut self) {
        let cancelled_waiting =
            self.waiting.iter().any(|(_, req)| req.cancel.load(Relaxed));
        if cancelled_waiting {
            let mut kept = VecDeque::with_capacity(self.waiting.len());
            for (at, req) in std::mem::take(&mut self.waiting) {
                if req.cancel.load(Relaxed) {
                    self.metrics.engine_cancelled.fetch_add(1, Relaxed);
                    self.metrics.mark_failed();
                    let _ = req.resp.send(Err(anyhow!("request cancelled: client disconnected")));
                } else {
                    kept.push_back((at, req));
                }
            }
            self.waiting = kept;
        }
        if self.active.iter().any(|seq| seq.cancel.load(Relaxed)) {
            let mut kept = Vec::with_capacity(self.active.len());
            for seq in std::mem::take(&mut self.active) {
                if seq.cancel.load(Relaxed) {
                    self.metrics.engine_cancelled.fetch_add(1, Relaxed);
                    self.fail(seq, "request cancelled: client disconnected");
                } else {
                    kept.push(seq);
                }
            }
            self.active = kept;
        }
    }

    /// Fail every queued and active sequence (models unavailable).
    pub(crate) fn fail_all(&mut self, why: &str) {
        for (_, req) in std::mem::take(&mut self.waiting) {
            self.metrics.mark_failed();
            let _ = req.resp.send(Err(anyhow!("{why}")));
        }
        for seq in std::mem::take(&mut self.active) {
            self.fail(seq, why);
        }
        self.update_gauges();
    }

    /// Admit waiting requests, highest priority first (FIFO within a
    /// class), bounded by `max_prefills_per_tick` so long prefills can't
    /// starve the active set's decode steps during overload.
    fn admit(&mut self, models: &mut dyn EngineModels) {
        let mut budget = self.cfg.max_prefills_per_tick;
        while budget > 0 && self.active.len() < self.cfg.max_active_seqs && !self.waiting.is_empty()
        {
            let Some(state) = self.pool.lease() else { break };
            let idx = self
                .waiting
                .iter()
                .enumerate()
                .max_by_key(|(i, (_, r))| (r.priority, std::cmp::Reverse(*i)))
                .map(|(i, _)| i)
                .expect("loop guard: waiting is non-empty");
            let Some((enqueued, req)) = self.waiting.remove(idx) else {
                // unreachable given the index above, but a leaked slot is
                // the wrong failure mode if that invariant ever slips
                self.pool.release(state);
                break;
            };
            budget -= 1;
            self.admit_one(models, req, state, enqueued);
        }
    }

    /// Prefill one request into its leased slot and move it to the active
    /// set (or straight to finished when `max_new == 1`).
    fn admit_one(
        &mut self,
        models: &mut dyn EngineModels,
        req: GenRequest,
        mut state: DecodeState,
        enqueued: Instant,
    ) {
        let id = self.next_id;
        self.next_id += 1;
        let adm_us = enqueued.elapsed().as_micros() as u64;
        if req.trace != 0 {
            self.metrics.spans.record(Span {
                trace: req.trace,
                kind: SpanKind::AdmissionWait,
                start_us: obs::now_us().saturating_sub(adm_us),
                dur_us: adm_us,
                aux: 0,
            });
        }
        let kernel = self.metrics.kernel.clone();
        let t0 = Instant::now();
        let run: Result<(SeqSite, Matrix)> = (|| {
            match req.scheme.static_spec() {
                Some((spec, qmax)) => {
                    let alpha = spec.alpha;
                    ensure!(
                        alpha.is_finite() && (0.0..=1.0).contains(&alpha),
                        "bad alpha {alpha}"
                    );
                    ensure!(
                        (qmax - 127.0).abs() < 0.5,
                        "native static path serves the INT8 grid (qmax 127), got {qmax}"
                    );
                    let model = models.static_model(&req.key.weight_set, &spec)?;
                    let logits = model.forward_incremental_with(&req.tokens, &mut state, true)?;
                    Ok((SeqSite::Integer, logits))
                }
                None => {
                    let mut site = SchemeSite::build(req.scheme, Some(kernel))?;
                    let model = models.native_model(&req.key.weight_set)?;
                    let logits =
                        model.forward_incremental_with(&req.tokens, &mut state, site.site(), true)?;
                    Ok((SeqSite::Native(site), logits))
                }
            }
        })();
        match run {
            Err(e) => {
                self.metrics.mark_failed();
                let _ = req.resp.send(Err(e));
                self.pool.release(state);
            }
            Ok((site, logits)) => {
                let prefill_us = t0.elapsed().as_micros() as u64;
                self.metrics.ttft.record_us(req.submitted.elapsed().as_micros() as u64);
                if req.trace != 0 {
                    self.metrics.spans.record(Span {
                        trace: req.trace,
                        kind: SpanKind::Prefill,
                        start_us: obs::now_us().saturating_sub(prefill_us),
                        dur_us: prefill_us,
                        aux: req.tokens.len() as u64,
                    });
                }
                let tok = block::argmax(logits.row(logits.rows - 1)) as u32;
                let seq = GenSeq {
                    id,
                    scheme: req.scheme,
                    key: req.key,
                    max_new: req.max_new,
                    generated: vec![tok],
                    state,
                    site,
                    next: tok,
                    resp: req.resp,
                    events: req.events,
                    cancel: req.cancel,
                    submitted: req.submitted,
                    trace: req.trace,
                    last_token_at: Instant::now(),
                };
                if let Some(ev) = &seq.events {
                    let _ = ev.send(GenEvent { seq: id, token: tok });
                }
                if seq.generated.len() >= seq.max_new {
                    self.finish(seq);
                } else {
                    self.active.push(seq);
                }
            }
        }
    }

    /// One batched decode step per scheme group: all sequences sharing a
    /// [`SchemeKey`] stack their next tokens into one M=N forward.
    fn step(&mut self, models: &mut dyn EngineModels) {
        if self.active.is_empty() {
            return;
        }
        // partition the active set by key in one pass (admission order is
        // preserved within each group)
        let mut groups: Vec<(SchemeKey, Vec<GenSeq>)> = Vec::new();
        for seq in std::mem::take(&mut self.active) {
            match groups.iter_mut().find(|(k, _)| *k == seq.key) {
                Some((_, group)) => group.push(seq),
                None => {
                    let key = seq.key.clone();
                    groups.push((key, vec![seq]));
                }
            }
        }
        for (key, mut group) in groups {
            let traced = group.iter().any(|s| s.trace != 0);
            if traced {
                gemm_timing_enable(true);
            }
            let t0 = Instant::now();
            let result = Self::step_group(models, &key, &mut group, &self.metrics);
            let fwd_us = t0.elapsed().as_micros() as u64;
            self.metrics.engine_steps.fetch_add(1, Relaxed);
            self.metrics.engine_stepped_seqs.fetch_add(group.len() as u64, Relaxed);
            self.metrics.engine_decode_time_us.fetch_add(fwd_us, Relaxed);
            self.metrics.batch_forward.record_us(fwd_us);
            if traced {
                let (gemm_calls, gemm_ns) = gemm_timing_take();
                gemm_timing_enable(false);
                if gemm_calls > 0 {
                    let start_us = obs::now_us().saturating_sub(fwd_us);
                    for seq in group.iter().filter(|s| s.trace != 0) {
                        self.metrics.spans.record(Span {
                            trace: seq.trace,
                            kind: SpanKind::Gemm,
                            start_us,
                            dur_us: gemm_ns / 1_000,
                            aux: gemm_calls,
                        });
                    }
                }
            }
            match result {
                Ok(()) => {
                    self.metrics.engine_decoded_tokens.fetch_add(group.len() as u64, Relaxed);
                    for seq in group {
                        if seq.generated.len() >= seq.max_new {
                            self.finish(seq);
                        } else {
                            self.active.push(seq);
                        }
                    }
                }
                Err(e) => {
                    let why = format!("{e}");
                    for seq in group {
                        self.fail(seq, &why);
                    }
                }
            }
        }
    }

    fn step_group(
        models: &mut dyn EngineModels,
        key: &SchemeKey,
        seqs: &mut [GenSeq],
        metrics: &Metrics,
    ) -> Result<()> {
        let scheme = seqs[0].scheme;
        let tokens: Vec<u32> = seqs.iter().map(|s| s.next).collect();
        let logits = match scheme.static_spec() {
            Some((spec, _)) => {
                let model = models.static_model(&key.weight_set, &spec)?;
                let mut states: Vec<&mut DecodeState> =
                    seqs.iter_mut().map(|s| &mut s.state).collect();
                model.forward_step_batched(&tokens, &mut states)?
            }
            None => {
                let model = models.native_model(&key.weight_set)?;
                let (mut states, mut sites): (Vec<&mut DecodeState>, Vec<&mut SeqSite>) =
                    seqs.iter_mut().map(|s| (&mut s.state, &mut s.site)).unzip();
                let mut hook = |row: usize, idx: usize, x: Matrix| match &mut *sites[row] {
                    SeqSite::Native(ss) => ss.site().apply(idx, x),
                    SeqSite::Integer => x,
                };
                // identity sites transform nothing — skip the per-row
                // split on the fp path entirely
                let hook_opt: Option<&mut dyn FnMut(usize, usize, Matrix) -> Matrix> =
                    if matches!(scheme, ActScheme::Fp) { None } else { Some(&mut hook) };
                model.forward_step_batched(&tokens, &mut states, hook_opt)?
            }
        };
        for (i, s) in seqs.iter_mut().enumerate() {
            let tok = block::argmax(logits.row(i)) as u32;
            s.next = tok;
            s.generated.push(tok);
            let gap_us = s.last_token_at.elapsed().as_micros() as u64;
            s.last_token_at = Instant::now();
            metrics.inter_token.record_us(gap_us);
            if s.trace != 0 {
                metrics.spans.record(Span {
                    trace: s.trace,
                    kind: SpanKind::DecodeToken,
                    start_us: obs::now_us().saturating_sub(gap_us),
                    dur_us: gap_us,
                    aux: s.generated.len() as u64 - 1,
                });
            }
            if let Some(ev) = &s.events {
                let _ = ev.send(GenEvent { seq: s.id, token: tok });
            }
        }
        Ok(())
    }

    fn finish(&mut self, seq: GenSeq) {
        let aux = match &seq.site {
            SeqSite::Native(s) => s.aux(),
            SeqSite::Integer => 0.0,
        };
        self.metrics.mark_completed();
        self.metrics.record_latency(seq.submitted.elapsed().as_micros() as u64);
        let _ = seq.resp.send(Ok(EvalResponse {
            nll: Vec::new(),
            aux,
            generated: seq.generated,
        }));
        self.pool.release(seq.state);
    }

    fn fail(&mut self, seq: GenSeq, why: &str) {
        self.metrics.mark_failed();
        let _ = seq.resp.send(Err(anyhow!("{why}")));
        self.pool.release(seq.state);
    }

    fn update_gauges(&self) {
        self.metrics.engine_active_seqs.store(self.active.len() as u64, Relaxed);
        self.metrics.engine_queue_depth.store(self.waiting.len() as u64, Relaxed);
        self.metrics.kv_pool_in_use.store(self.pool.in_use() as u64, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use std::sync::mpsc::{channel, sync_channel, Receiver};

    use std::collections::HashMap;

    use super::*;
    use crate::corpus::CorpusGen;
    use crate::model::weights::synthetic_weights;
    use crate::model::IdentitySite;
    use crate::quant::registry::{self, SchemeId};
    use crate::quant::Bits;

    fn cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            seq_len: 24,
            eval_batch: 2,
        }
    }

    /// Minimal [`EngineModels`]: one native model plus a spec-keyed cache
    /// of registry-built static models — mirroring the executor's
    /// calibration stream.
    struct TestModels {
        native: NativeModel,
        static_ms: HashMap<(u16, i64, usize), QuantizedModel>,
    }

    impl TestModels {
        fn new(seed: u64) -> TestModels {
            TestModels {
                native: NativeModel::new(synthetic_weights(cfg(), seed)),
                static_ms: HashMap::new(),
            }
        }
    }

    impl EngineModels for TestModels {
        fn native_model(&mut self, _ws: &str) -> Result<&NativeModel> {
            Ok(&self.native)
        }

        fn static_model(&mut self, _ws: &str, spec: &StaticSpec) -> Result<&QuantizedModel> {
            let key = spec.cache_key();
            if !self.static_ms.contains_key(&key) {
                let mut gen = CorpusGen::new(cfg().vocab, 0x5CA1E);
                let calib: Vec<Vec<u32>> = (0..4).map(|_| gen.sequence(cfg().seq_len)).collect();
                let qm = registry::build_static_model(
                    &self.native.weights,
                    Bits::Int8,
                    Bits::Int8,
                    spec,
                    &calib,
                )?;
                self.static_ms.insert(key, qm);
            }
            Ok(self.static_ms.get(&key).expect("installed above"))
        }
    }

    #[allow(clippy::type_complexity)]
    fn gen_req(
        tokens: Vec<u32>,
        scheme: ActScheme,
        max_new: usize,
    ) -> (GenRequest, Receiver<Result<EvalResponse>>, Receiver<GenEvent>) {
        let (resp_tx, resp_rx) = sync_channel(1);
        let (ev_tx, ev_rx) = channel();
        let key = {
            let mut k = scheme.key("w");
            k.generate = true;
            k
        };
        let req = GenRequest {
            tokens,
            scheme,
            key,
            max_new,
            resp: resp_tx,
            events: Some(ev_tx),
            cancel: Arc::new(AtomicBool::new(false)),
            submitted: Instant::now(),
            trace: 0,
            priority: 2,
        };
        (req, resp_rx, ev_rx)
    }

    fn engine(max_active: usize, max_waiting: usize, kv_pool_bytes: Option<usize>) -> Engine {
        Engine::new(
            EngineConfig {
                max_active_seqs: max_active,
                kv_pool_bytes,
                max_waiting,
                ..EngineConfig::default()
            },
            cfg(),
            Arc::new(Metrics::new()),
        )
    }

    #[test]
    fn pool_lease_release_accounting() {
        let mut pool = KvPool::new(2, cfg());
        let per_slot = 2 * 2 * 24 * 16 * 4; // 2(K+V) · layers · ctx · d · f32
        assert_eq!(pool.slot_bytes(), per_slot);
        assert_eq!(pool.memory_bytes(), 2 * per_slot);
        let a = pool.lease().expect("slot 0");
        let _b = pool.lease().expect("slot 1");
        assert!(pool.lease().is_none(), "exhausted pool must not lease");
        assert_eq!(pool.in_use(), 2);
        pool.release(a);
        assert_eq!(pool.in_use(), 1);
        let again = pool.lease().expect("released slot is reusable");
        assert!(again.is_empty(), "leased slots start at an empty prefix");
    }

    #[test]
    fn budget_clamps_pool_slots() {
        let per_slot = 2 * 2 * 24 * 16 * 4;
        let ec = EngineConfig {
            max_active_seqs: 8,
            kv_pool_bytes: Some(per_slot * 3 + 10),
            max_waiting: 4,
            ..EngineConfig::default()
        };
        assert_eq!(KvPool::with_config(&ec, cfg()).slots(), 3);
        // budget below one slot still yields a working pool
        let tiny = EngineConfig { kv_pool_bytes: Some(1), ..ec };
        assert_eq!(KvPool::with_config(&tiny, cfg()).slots(), 1);
    }

    #[test]
    fn queue_then_reject_when_pool_exhausted() {
        // one slot, queue of one: seq A runs, B queues, C is rejected
        let mut eng = engine(1, 1, None);
        let mut models = TestModels::new(3);
        let reference = |prompt: &[u32], n: usize| {
            models.native.generate_greedy(prompt, n, &mut IdentitySite).unwrap()
        };
        let ra = reference(&[1, 2, 3], 6);
        let rb = reference(&[4, 5], 4);
        let (a, a_rx, a_ev) = gen_req(vec![1, 2, 3], ActScheme::Fp, 6);
        let (b, b_rx, _b_ev) = gen_req(vec![4, 5], ActScheme::Fp, 4);
        let (c, c_rx, _c_ev) = gen_req(vec![6], ActScheme::Fp, 2);
        eng.submit(a);
        eng.tick(&mut models); // A admitted (prefill + first step)
        assert!(!eng.is_idle());
        eng.submit(b); // pool exhausted → queues
        eng.submit(c); // queue full → rejected immediately
        let err = c_rx.recv().expect("rejection must respond").unwrap_err();
        assert!(format!("{err}").contains("admission queue full"), "unexpected: {err}");
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        let resp_a = a_rx.recv().unwrap().unwrap();
        let resp_b = b_rx.recv().unwrap().unwrap();
        assert_eq!(resp_a.generated, ra, "A must match its solo decode");
        assert_eq!(resp_b.generated, rb, "B must match its solo decode");
        // streamed tokens equal the final payload
        let streamed: Vec<u32> = a_ev.try_iter().map(|e| e.token).collect();
        assert_eq!(streamed, resp_a.generated);
        assert_eq!(eng.metrics.engine_rejected.load(Relaxed), 1);
        assert_eq!(eng.metrics.kv_pool_in_use.load(Relaxed), 0);
    }

    #[test]
    fn mid_flight_join_keeps_sequences_bit_exact() {
        let mut eng = engine(4, 8, None);
        let mut models = TestModels::new(7);
        let ra = models.native.generate_greedy(&[1, 2, 3], 8, &mut IdentitySite).unwrap();
        let rb = models.native.generate_greedy(&[9, 9], 5, &mut IdentitySite).unwrap();
        let (a, a_rx, _) = gen_req(vec![1, 2, 3], ActScheme::Fp, 8);
        eng.submit(a);
        eng.tick(&mut models);
        eng.tick(&mut models); // A is mid-decode…
        let (b, b_rx, _) = gen_req(vec![9, 9], ActScheme::Fp, 5);
        eng.submit(b); // …when B joins the running batch
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert_eq!(a_rx.recv().unwrap().unwrap().generated, ra);
        assert_eq!(b_rx.recv().unwrap().unwrap().generated, rb);
        // at least one step ran with both sequences stacked
        assert!(eng.metrics.batch_occupancy() > 1.0, "join must share steps");
    }

    #[test]
    fn scheme_groups_step_independently_and_stay_exact() {
        // fp and crossquant-static sequences decode concurrently; each
        // matches its own solo reference
        let mut eng = engine(4, 8, None);
        let mut models = TestModels::new(11);
        let r_fp = models.native.generate_greedy(&[1, 2, 3, 4], 6, &mut IdentitySite).unwrap();
        let r_st = models
            .static_model("w", &StaticSpec::new(SchemeId::CrossQuantStatic, 0.15, 0))
            .unwrap()
            .generate_greedy(&[1, 2, 3, 4], 6)
            .unwrap();
        let (a, a_rx, _) =
            gen_req(vec![1, 2, 3, 4], ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 }, 6);
        let (b, b_rx, _) = gen_req(vec![1, 2, 3, 4], ActScheme::Fp, 6);
        eng.submit(a);
        eng.submit(b);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert_eq!(a_rx.recv().unwrap().unwrap().generated, r_st);
        assert_eq!(b_rx.recv().unwrap().unwrap().generated, r_fp);
    }

    #[test]
    fn registry_schemes_decode_bit_exact_in_the_engine() {
        // a gptq sequence decoded by the engine matches its solo decode on
        // the same registry-built model
        let mut eng = engine(4, 8, None);
        let mut models = TestModels::new(17);
        let spec = StaticSpec::new(SchemeId::Gptq, 0.15, 0);
        let r = models.static_model("w", &spec).unwrap().generate_greedy(&[2, 3, 4], 5).unwrap();
        let (a, a_rx, _) =
            gen_req(vec![2, 3, 4], ActScheme::Gptq { alpha: 0.15, qmax: 127.0 }, 5);
        eng.submit(a);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert_eq!(a_rx.recv().unwrap().unwrap().generated, r);
    }

    #[test]
    fn cancelled_sequence_is_reaped_and_releases_its_slot() {
        let mut eng = engine(2, 4, None);
        let mut models = TestModels::new(5);
        let (a, a_rx, _a_ev) = gen_req(vec![1, 2, 3], ActScheme::Fp, 16);
        let cancel = a.cancel.clone();
        eng.submit(a);
        eng.tick(&mut models); // admitted, mid-decode
        assert_eq!(eng.pool.in_use(), 1);
        cancel.store(true, Relaxed);
        eng.tick(&mut models); // reaped before the next step
        assert!(eng.is_idle(), "cancelled sequence must leave the active set");
        assert_eq!(eng.pool.in_use(), 0, "cancel must release the KV slot");
        assert_eq!(eng.metrics.engine_cancelled.load(Relaxed), 1);
        let err = a_rx.recv().unwrap().unwrap_err();
        assert!(format!("{err}").contains("cancelled"), "unexpected: {err}");
    }

    #[test]
    fn cancelled_queued_request_never_admits() {
        // one slot: A occupies it, B queues, B's client disconnects
        let mut eng = engine(1, 4, None);
        let mut models = TestModels::new(5);
        let (a, a_rx, _) = gen_req(vec![1, 2, 3], ActScheme::Fp, 6);
        let (b, b_rx, _) = gen_req(vec![4, 5], ActScheme::Fp, 4);
        let cancel_b = b.cancel.clone();
        eng.submit(a);
        eng.tick(&mut models);
        eng.submit(b);
        cancel_b.store(true, Relaxed);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert!(a_rx.recv().unwrap().is_ok(), "A is unaffected by B's cancel");
        let err = b_rx.recv().unwrap().unwrap_err();
        assert!(format!("{err}").contains("cancelled"), "unexpected: {err}");
        assert_eq!(eng.metrics.engine_cancelled.load(Relaxed), 1);
    }

    #[test]
    fn traced_sequence_emits_contiguous_spans() {
        let mut eng = engine(2, 4, None);
        let mut models = TestModels::new(19);
        let (mut a, a_rx, _) = gen_req(vec![1, 2, 3], ActScheme::Fp, 6);
        a.trace = 0xFEED;
        eng.submit(a);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        a_rx.recv().unwrap().unwrap();
        let spans = eng.metrics.spans.for_trace(0xFEED);
        let kind_count =
            |k: SpanKind| spans.iter().filter(|s| s.kind == k).count();
        assert_eq!(kind_count(SpanKind::QueueWait), 1);
        assert_eq!(kind_count(SpanKind::AdmissionWait), 1);
        assert_eq!(kind_count(SpanKind::Prefill), 1);
        // 6 tokens: one at prefill, five decode steps
        assert_eq!(kind_count(SpanKind::DecodeToken), 5);
        // histograms observed alongside the spans
        assert_eq!(eng.metrics.ttft.total.count(), 1);
        assert_eq!(eng.metrics.inter_token.total.count(), 5);
        assert!(eng.metrics.batch_forward.total.count() >= 5);
        // an untraced request leaves the ring untouched
        let before = eng.metrics.spans.recorded();
        let (b, b_rx, _) = gen_req(vec![4, 5], ActScheme::Fp, 3);
        eng.submit(b);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        b_rx.recv().unwrap().unwrap();
        assert_eq!(eng.metrics.spans.recorded(), before);
    }

    #[test]
    fn full_queue_evicts_lowest_priority_first() {
        // one slot, queue of two: A occupies the slot, B (p0) and C (p1)
        // fill the queue. D (p3) arrives: B — the lowest class — is
        // evicted to make room. Then E (p0) arrives: nothing queued is
        // lower, so E itself is shed. No high-priority request ever sees
        // a failure.
        let mut eng = engine(1, 2, None);
        let mut models = TestModels::new(3);
        let (a, a_rx, _) = gen_req(vec![1, 2, 3], ActScheme::Fp, 6);
        eng.submit(a);
        eng.tick(&mut models); // A admitted
        let (mut b, b_rx, _) = gen_req(vec![4, 5], ActScheme::Fp, 4);
        b.priority = 0;
        let (mut c, c_rx, _) = gen_req(vec![6, 7], ActScheme::Fp, 4);
        c.priority = 1;
        eng.submit(b);
        eng.submit(c); // queue now full
        let (mut d, d_rx, _) = gen_req(vec![8], ActScheme::Fp, 2);
        d.priority = 3;
        eng.submit(d); // evicts B
        let err = b_rx.recv().expect("evicted request must respond").unwrap_err();
        assert!(format!("{err}").contains("request shed (priority 0)"), "unexpected: {err}");
        let (mut e, e_rx, _) = gen_req(vec![9], ActScheme::Fp, 2);
        e.priority = 0;
        eng.submit(e); // queue holds p1+p3 — the incoming p0 is shed
        let err = e_rx.recv().expect("shed request must respond").unwrap_err();
        assert!(format!("{err}").contains("request shed (priority 0)"), "unexpected: {err}");
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert!(a_rx.recv().unwrap().is_ok());
        assert!(c_rx.recv().unwrap().is_ok());
        assert!(d_rx.recv().unwrap().is_ok(), "high priority must never fail");
        assert_eq!(eng.metrics.shed_by_priority[0].load(Relaxed), 2);
        assert_eq!(eng.metrics.shed_by_priority[1].load(Relaxed), 0);
        assert_eq!(eng.metrics.shed_by_priority[3].load(Relaxed), 0);
        assert_eq!(eng.metrics.engine_rejected.load(Relaxed), 2);
    }

    #[test]
    fn admission_is_priority_ordered_and_prefill_bounded() {
        // one admission per tick (the fairness knob) and a 1-seq active
        // cap: of two queued single-token requests, the interactive one
        // admits on the first tick, the low one only on the second.
        let mut eng = Engine::new(
            EngineConfig {
                max_active_seqs: 1,
                kv_pool_bytes: None,
                max_waiting: 8,
                max_prefills_per_tick: 1,
            },
            cfg(),
            Arc::new(Metrics::new()),
        );
        let mut models = TestModels::new(7);
        let (mut b, b_rx, _) = gen_req(vec![1, 2], ActScheme::Fp, 1);
        b.priority = 1;
        let (mut c, c_rx, _) = gen_req(vec![3, 4], ActScheme::Fp, 1);
        c.priority = 3;
        eng.submit(b);
        eng.submit(c);
        eng.tick(&mut models);
        assert!(c_rx.try_recv().is_ok(), "interactive request admits first");
        assert!(b_rx.try_recv().is_err(), "low request must wait for the next tick");
        eng.tick(&mut models);
        assert!(b_rx.try_recv().is_ok());
    }

    #[test]
    fn burn_mode_sheds_best_effort_and_serves_the_rest() {
        use crate::obs::SloSpec;
        let mut eng = engine(2, 4, None);
        let mut models = TestModels::new(5);
        // impossible TTFT target + a stream of violations: every window
        // burns at 100x budget, far past the threshold
        eng.metrics.slo.configure(SloSpec {
            ttft_p99_us: 1,
            inter_token_p99_us: 1_000_000,
            error_rate: 0.5,
            burn_threshold: 10.0,
        });
        for _ in 0..50 {
            eng.metrics.ttft.record_us(10_000);
        }
        eng.tick(&mut models); // refreshes shed_mode from the burn report
        let (mut a, a_rx, _) = gen_req(vec![1, 2], ActScheme::Fp, 2);
        a.priority = 0;
        eng.submit(a);
        let err = a_rx.recv().expect("shed must respond").unwrap_err();
        assert!(format!("{err}").contains("SLO burn rate"), "unexpected: {err}");
        // normal-priority traffic still flows while shedding
        let (b, b_rx, _) = gen_req(vec![3, 4], ActScheme::Fp, 2);
        eng.submit(b);
        while !eng.is_idle() {
            eng.tick(&mut models);
        }
        assert!(b_rx.recv().unwrap().is_ok());
        assert_eq!(eng.metrics.shed_by_priority[0].load(Relaxed), 1);
    }

    #[test]
    fn malformed_static_request_fails_cleanly() {
        let mut eng = engine(2, 4, None);
        let mut models = TestModels::new(13);
        // qmax off the INT8 grid: structured error at admission, slot freed
        let (a, a_rx, _) =
            gen_req(vec![1, 2], ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 50.0 }, 3);
        eng.submit(a);
        eng.tick(&mut models);
        assert!(a_rx.recv().unwrap().is_err());
        assert!(eng.is_idle());
        assert_eq!(eng.pool.in_use(), 0, "failed admission must release its slot");
    }
}
