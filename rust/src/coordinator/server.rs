//! Line-protocol TCP front-end over the eval coordinator — the serving
//! shell: external clients stream token sequences in, batched quantized
//! evaluations come back, Python nowhere in sight.
//!
//! Protocol: one JSON object per line.
//!
//! request:  {"tokens": [1,2,3,...], "scheme": "crossquant"|"per-token"|
//!            "crossquant-static"|"fp"|"remove-kernel", "alpha": 0.15,
//!            "qmax": 127.0, "theta": 0.004, "weight_set": "w16"}
//!           …with "max_new_tokens": N present, the tokens are a prompt
//!           and the request is greedy generation instead of scoring
//!           {"cmd": "metrics"}   |   {"cmd": "ping"}
//! response: {"ok": true, "nll": [...], "ppl": ..., "aux": ...}
//!           {"ok": true, "generated": [...], "prompt_tokens": N, "aux": ...}
//!           {"ok": false, "error": "..."}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{anyhow, Result};

use super::scheduler::{EvalCoordinator, EvalRequest};
use super::ActScheme;
use crate::util::Json;

pub struct EvalServer {
    pub coordinator: EvalCoordinator,
}

impl EvalServer {
    pub fn new(coordinator: EvalCoordinator) -> EvalServer {
        EvalServer { coordinator }
    }

    /// Serve forever on `listener`; one thread per connection (the PJRT
    /// executor thread is the actual concurrency bottleneck, and the
    /// batcher merges concurrent clients into shared batches — that is the
    /// point of the coordinator).
    pub fn serve(&self, listener: TcpListener) -> Result<()> {
        for stream in listener.incoming() {
            let stream = stream?;
            let coordinator = self.coordinator.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(coordinator, stream);
            });
        }
        Ok(())
    }
}

fn handle_connection(coordinator: EvalCoordinator, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match handle_line(&coordinator, &line) {
            Ok(json) => json,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(format!("{e}"))),
            ]),
        };
        writer.write_all(response.render().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    let _ = peer;
    Ok(())
}

/// Parse one request line, run it, build the response (pure except for the
/// coordinator call — unit-testable).
pub fn handle_line(coordinator: &EvalCoordinator, line: &str) -> Result<Json> {
    let req = Json::parse(line)?;

    if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])),
            "metrics" => Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("metrics", Json::str(coordinator.metrics.summary())),
            ])),
            other => Err(anyhow!("unknown cmd '{other}'")),
        };
    }

    let tokens: Vec<u32> = req
        .req("tokens")?
        .as_arr()
        .ok_or_else(|| anyhow!("'tokens' must be an array"))?
        .iter()
        .map(|t| t.as_usize().map(|v| v as u32).ok_or_else(|| anyhow!("bad token")))
        .collect::<Result<_>>()?;

    let scheme_name = req.get("scheme").and_then(|s| s.as_str()).unwrap_or("crossquant");
    let alpha = req.get("alpha").and_then(|a| a.as_f64()).unwrap_or(0.15) as f32;
    let qmax = req.get("qmax").and_then(|a| a.as_f64()).unwrap_or(127.0) as f32;
    let theta = req.get("theta").and_then(|a| a.as_f64()).unwrap_or(0.5 / 127.0) as f32;
    let scheme = match scheme_name {
        "fp" => ActScheme::Fp,
        "crossquant" => ActScheme::CrossQuant { alpha, qmax },
        "crossquant-fused" => ActScheme::CrossQuantFused { alpha, qmax },
        "crossquant-static" => ActScheme::CrossQuantStatic { alpha, qmax },
        "per-token" => ActScheme::CrossQuant { alpha: 1.0, qmax },
        "remove-kernel" => ActScheme::RemoveKernel { theta },
        other => return Err(anyhow!("unknown scheme '{other}'")),
    };
    let weight_set =
        req.get("weight_set").and_then(|w| w.as_str()).unwrap_or("w16").to_string();

    // "max_new_tokens" present ⇒ greedy generation; absent ⇒ scoring.
    // Context overflow (prompt + max_new_tokens > n_ctx) is rejected by
    // `submit` as a structured {"ok": false} error, never a panic.
    if let Some(max_new) = req.get("max_new_tokens") {
        let max_new = max_new
            .as_usize()
            .ok_or_else(|| anyhow!("'max_new_tokens' must be a non-negative integer"))?;
        let prompt_tokens = tokens.len();
        let resp = coordinator
            .submit(EvalRequest::generate(tokens, scheme, weight_set, max_new))?
            .wait()?;
        return Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "generated",
                Json::arr(resp.generated.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("prompt_tokens", Json::num(prompt_tokens as f64)),
            ("aux", Json::num(resp.aux as f64)),
        ]));
    }

    let resp = coordinator.submit(EvalRequest::score(tokens, scheme, weight_set))?.wait()?;
    let mean = resp.nll.iter().map(|&v| v as f64).sum::<f64>() / resp.nll.len().max(1) as f64;
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("nll", Json::arr(resp.nll.iter().map(|&v| Json::num(v as f64)).collect())),
        ("ppl", Json::num(mean.exp())),
        ("aux", Json::num(resp.aux as f64)),
    ]))
}
