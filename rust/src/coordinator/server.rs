//! Line-protocol TCP front-end over the eval coordinator — the serving
//! shell: external clients stream token sequences in, batched quantized
//! evaluations come back, Python nowhere in sight.
//!
//! Protocol: one JSON object per line.
//!
//! request:  {"tokens": [1,2,3,...], "scheme": "crossquant"|"per-token"|
//!            "crossquant-static"|"fp"|"remove-kernel"|"smoothquant"|
//!            "awq"|"gptq"|"lorc", "alpha": 0.15, "qmax": 127.0,
//!            "theta": 0.004, "rank": 8, "weight_set": "w16"}
//!           (scheme names are the canonical `quant::registry` names,
//!           shared with the CLI and the artifact scheme-ID field)
//!           …with "max_new_tokens": N present, the tokens are a prompt
//!           and the request is greedy generation instead of scoring;
//!           adding "stream": true streams the decode as it happens;
//!           an optional "trace" field (hex string or integer) attaches a
//!           trace id — per-stage spans record under it and the response
//!           echoes it back;
//!           an optional "priority" field (0–3 or "batch"/"low"/"normal"/
//!           "high") sets the scheduling class — under overload the
//!           engine sheds lowest-priority-first (default "normal")
//!           {"cmd": "metrics"}   |   {"cmd": "ping"}
//!           {"cmd": "metrics", "format": "prometheus"} → text exposition
//!           {"cmd": "slo"} → SLO spec + multi-window burn-rate report
//!           {"cmd": "metrics_reset"} → zero the accumulated counters and
//!           latency windows (gauges and configuration survive) — load
//!           harnesses call this before a run
//!           {"cmd": "trace", "id": "<hex>"} → that trace's spans
//!           ("id" absent/0 dumps the whole ring; "format": "chrome"
//!           renders Chrome trace_event JSON instead)
//! response: {"ok": true, "nll": [...], "ppl": ..., "aux": ...}
//!           {"ok": true, "generated": [...], "prompt_tokens": N, "aux": ...}
//!           {"ok": false, "error": "..."}
//!
//! Streaming responses ("stream": true): one `{"token": t, "seq": s}`
//! line per decoded token as the continuous-batching engine produces it,
//! then a final summary line
//! `{"ok": true, "done": true, "seq": s, "generated": [...],
//!   "prompt_tokens": N, "aux": ...}`. Errors terminate the stream with
//! the standard `{"ok": false, ...}` line.
//!
//! Connections are capped (default 256, `EvalServer::with_max_connections`):
//! over-limit clients receive a structured
//! `{"ok": false, "error": "server at connection capacity"}` line and are
//! disconnected instead of spawning threads without bound.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::scheduler::{EvalCoordinator, EvalRequest, RequestKind};
use super::ActScheme;
use crate::obs::{self, trace::chrome_trace_json};
use crate::quant::registry::SchemeId;
use crate::util::{FaultAction, FaultInjector, Json};

/// Default cap on concurrent client connections.
pub const DEFAULT_MAX_CONNECTIONS: usize = 256;

/// Default idle read timeout: a connection that sends nothing for this
/// long is closed with a structured error, freeing its slot under the
/// connection cap instead of pinning it until the cap refuses live
/// traffic.
pub const DEFAULT_IDLE_TIMEOUT_SECS: u64 = 300;

pub struct EvalServer {
    pub coordinator: EvalCoordinator,
    max_connections: usize,
    idle_timeout: Option<Duration>,
    fault: Arc<FaultInjector>,
    active_connections: Arc<AtomicUsize>,
}

impl EvalServer {
    pub fn new(coordinator: EvalCoordinator) -> EvalServer {
        EvalServer {
            coordinator,
            max_connections: DEFAULT_MAX_CONNECTIONS,
            idle_timeout: Some(Duration::from_secs(DEFAULT_IDLE_TIMEOUT_SECS)),
            fault: Arc::new(FaultInjector::none()),
            active_connections: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Cap concurrent connections (clamped to ≥ 1).
    pub fn with_max_connections(mut self, max: usize) -> EvalServer {
        self.max_connections = max.max(1);
        self
    }

    /// Idle read timeout per connection (`None` disables — the pre-PR-7
    /// behaviour where a dead client pinned its slot forever).
    pub fn with_idle_timeout(mut self, timeout: Option<Duration>) -> EvalServer {
        self.idle_timeout = timeout;
        self
    }

    /// Install a deterministic fault-injection plan (worker mode threads
    /// the parsed `CROSSQUANT_FAULT` plan through here; the default
    /// injector never fires).
    pub fn with_fault_injector(mut self, fault: Arc<FaultInjector>) -> EvalServer {
        self.fault = fault;
        self
    }

    /// Connections currently being served (observability / tests).
    pub fn active_connections(&self) -> usize {
        self.active_connections.load(Ordering::SeqCst)
    }

    /// Serve forever on `listener`; one thread per connection, capped at
    /// `max_connections` — over-limit clients get a structured error line
    /// and are disconnected, so a connection flood cannot spawn threads
    /// without bound. (The executor thread is the actual compute
    /// bottleneck; the batcher and the generation engine merge concurrent
    /// clients into shared executions — that is the point of the
    /// coordinator.)
    pub fn serve(&self, listener: TcpListener) -> Result<()> {
        for stream in listener.incoming() {
            let mut stream = stream?;
            // optimistic reserve: revert when over the cap (keeps the
            // accept loop free of locks)
            let n = self.active_connections.fetch_add(1, Ordering::SeqCst);
            if n >= self.max_connections {
                self.active_connections.fetch_sub(1, Ordering::SeqCst);
                let refusal = Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::str("server at connection capacity")),
                    // capacity is transient — a router should try elsewhere
                    ("retryable", Json::Bool(true)),
                ]);
                let _ = stream.write_all(refusal.render().as_bytes());
                let _ = stream.write_all(b"\n");
                continue; // drop closes the socket
            }
            let coordinator = self.coordinator.clone();
            let active = self.active_connections.clone();
            let idle_timeout = self.idle_timeout;
            let fault = self.fault.clone();
            std::thread::spawn(move || {
                let _ = handle_connection(coordinator, stream, idle_timeout, fault);
                active.fetch_sub(1, Ordering::SeqCst);
            });
        }
        Ok(())
    }
}

fn handle_connection(
    coordinator: EvalCoordinator,
    stream: TcpStream,
    idle_timeout: Option<Duration>,
    fault: Arc<FaultInjector>,
) -> Result<()> {
    let peer = stream.peer_addr().ok();
    stream.set_read_timeout(idle_timeout)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF: client closed cleanly
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // idle client: free the slot under the connection cap
                let _ = write_line(
                    &mut writer,
                    &Json::obj(vec![
                        ("ok", Json::Bool(false)),
                        ("error", Json::str("idle timeout: closing connection")),
                        ("retryable", Json::Bool(true)),
                    ]),
                );
                break;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            continue;
        }
        // fault injection counts *data* requests only — control frames
        // (ping/metrics heartbeats) must never perturb a deterministic
        // fault schedule
        let parsed = Json::parse(&line);
        let is_data = matches!(&parsed, Ok(j) if j.get("cmd").is_none());
        let mut action = FaultAction::None;
        if is_data {
            action = fault.apply_local(fault.on_data_request());
            if action == FaultAction::DropConnection {
                return Ok(()); // drop closes the socket, no response line
            }
        }
        // streamed generation writes its own lines; everything else is
        // one-request → one-response
        if action == FaultAction::None {
            let streamed = match &parsed {
                Ok(req) if wants_stream(req) => {
                    match handle_stream(&coordinator, &mut writer, req) {
                        Ok(()) => true,
                        Err(e) => {
                            write_line(&mut writer, &error_response(&e))?;
                            true
                        }
                    }
                }
                _ => false,
            };
            if streamed {
                continue;
            }
        }
        let response = match handle_line(&coordinator, &line) {
            Ok(json) => json,
            Err(e) => error_response(&e),
        };
        if action == FaultAction::TruncateResponse {
            // write half the rendered response with no newline, then close
            // — the client sees a torn frame and a dead connection
            let rendered = response.render();
            let half = &rendered.as_bytes()[..rendered.len() / 2];
            writer.write_all(half)?;
            writer.flush()?;
            return Ok(());
        }
        write_line(&mut writer, &response)?;
    }
    let _ = peer;
    Ok(())
}

/// Structured error line. `retryable` tells a fleet router whether the
/// request is safe and useful to retry on another worker: transient
/// conditions (dead executor, capacity) are; deterministic request
/// errors (bad scheme, context overflow) are not.
fn error_response(e: &anyhow::Error) -> Json {
    let msg = format!("{e}");
    let retryable = msg.contains("executor exited")
        || msg.contains("engine at capacity")
        || msg.contains("coordinator shut down")
        || msg.contains("server at connection capacity")
        || msg.contains("request shed");
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
        ("retryable", Json::Bool(retryable)),
    ])
}

fn write_line(writer: &mut impl Write, json: &Json) -> Result<()> {
    writer.write_all(json.render().as_bytes())?;
    writer.write_all(b"\n")?;
    Ok(())
}

fn wants_stream(req: &Json) -> bool {
    req.get("stream") == Some(&Json::Bool(true))
}

/// Parse one evaluation request (scoring or generation) from its JSON
/// object — shared by the plain and streaming paths.
fn parse_request(req: &Json) -> Result<EvalRequest> {
    let tokens: Vec<u32> = req
        .req("tokens")?
        .as_arr()
        .ok_or_else(|| anyhow!("'tokens' must be an array"))?
        .iter()
        .map(|t| t.as_usize().map(|v| v as u32).ok_or_else(|| anyhow!("bad token")))
        .collect::<Result<_>>()?;

    let scheme_name = req.get("scheme").and_then(|s| s.as_str()).unwrap_or("crossquant");
    let alpha = req.get("alpha").and_then(|a| a.as_f64()).unwrap_or(0.15) as f32;
    let qmax = req.get("qmax").and_then(|a| a.as_f64()).unwrap_or(127.0) as f32;
    let theta = req.get("theta").and_then(|a| a.as_f64()).unwrap_or(0.5 / 127.0) as f32;
    let rank = req.get("rank").and_then(|r| r.as_usize()).unwrap_or(8);
    // one canonical name table (registry) shared by wire, CLI and artifact
    let id: SchemeId = scheme_name.parse()?;
    let scheme = match id {
        SchemeId::Fp => ActScheme::Fp,
        SchemeId::PerToken => ActScheme::CrossQuant { alpha: 1.0, qmax },
        SchemeId::CrossQuant => ActScheme::CrossQuant { alpha, qmax },
        SchemeId::CrossQuantFused => ActScheme::CrossQuantFused { alpha, qmax },
        SchemeId::CrossQuantStatic => ActScheme::CrossQuantStatic { alpha, qmax },
        SchemeId::RemoveKernel => ActScheme::RemoveKernel { theta },
        SchemeId::SmoothQuant => ActScheme::SmoothQuant { alpha, qmax },
        SchemeId::Awq => ActScheme::Awq { alpha, qmax },
        SchemeId::Gptq => ActScheme::Gptq { alpha, qmax },
        SchemeId::Lorc => ActScheme::Lorc { alpha, rank, qmax },
        other => {
            return Err(anyhow!(
                "scheme '{}' is an offline eval method, not servable over the wire",
                other.name()
            ))
        }
    };
    let weight_set =
        req.get("weight_set").and_then(|w| w.as_str()).unwrap_or("w16").to_string();
    // optional trace id (hex string, integer, or any stable name — see
    // `obs::parse_trace_field`); 0 = untraced
    let trace = req.get("trace").and_then(obs::parse_trace_field).unwrap_or(0);
    // optional scheduling class; a present-but-malformed field is a
    // deterministic request error, not a silent "normal"
    let priority = match req.get("priority") {
        Some(v) => super::parse_priority(v)
            .ok_or_else(|| anyhow!("'priority' must be 0-3 or batch/low/normal/high"))?,
        None => super::metrics::PRIORITY_DEFAULT,
    };

    // "max_new_tokens" present ⇒ greedy generation; absent ⇒ scoring.
    // Context overflow (prompt + max_new_tokens > n_ctx) is rejected by
    // `submit` as a structured {"ok": false} error, never a panic.
    if let Some(max_new) = req.get("max_new_tokens") {
        let max_new = max_new
            .as_usize()
            .ok_or_else(|| anyhow!("'max_new_tokens' must be a non-negative integer"))?;
        Ok(EvalRequest::generate(tokens, scheme, weight_set, max_new)
            .with_trace(trace)
            .with_priority(priority))
    } else {
        Ok(EvalRequest::score(tokens, scheme, weight_set).with_trace(trace).with_priority(priority))
    }
}

/// Streamed generation: one `{"token": ..., "seq": ...}` line per decoded
/// token, then the final summary line.
fn handle_stream(
    coordinator: &EvalCoordinator,
    writer: &mut impl Write,
    req: &Json,
) -> Result<()> {
    let eval_req = parse_request(req)?;
    anyhow::ensure!(
        matches!(eval_req.kind, RequestKind::Generate { .. }),
        "'stream': true requires 'max_new_tokens' (streaming is a generation feature)"
    );
    let prompt_tokens = eval_req.tokens.len();
    let trace = eval_req.trace;
    let (events, handle) = coordinator.submit_streaming(eval_req)?;
    let mut seq_id = 0u64;
    for ev in events.iter() {
        seq_id = ev.seq;
        let wrote = write_line(
            writer,
            &Json::obj(vec![
                ("token", Json::num(ev.token as f64)),
                ("seq", Json::num(ev.seq as f64)),
            ]),
        );
        if let Err(e) = wrote {
            // broken pipe mid-stream: the client is gone, so cancel the
            // sequence — the engine reaps it at the next tick and returns
            // its KV slot instead of decoding the rest for nobody
            handle.cancel();
            return Err(e);
        }
    }
    // the event sender is dropped when the sequence retires, so the
    // response is already resolved here
    let resp = handle.wait()?;
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("done", Json::Bool(true)),
        ("seq", Json::num(seq_id as f64)),
        (
            "generated",
            Json::arr(resp.generated.iter().map(|&t| Json::num(t as f64)).collect()),
        ),
        ("prompt_tokens", Json::num(prompt_tokens as f64)),
        ("aux", Json::num(resp.aux as f64)),
    ];
    if trace != 0 {
        fields.push(("trace", Json::str(obs::trace_id_string(trace))));
    }
    write_line(writer, &Json::obj(fields))
}

/// Parse one request line, run it, build the response (pure except for the
/// coordinator call — unit-testable).
pub fn handle_line(coordinator: &EvalCoordinator, line: &str) -> Result<Json> {
    let req = Json::parse(line)?;

    if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true)), ("pong", Json::Bool(true))])),
            "metrics" => {
                if req.get("format").and_then(|f| f.as_str()) == Some("prometheus") {
                    return Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("content_type", Json::str("text/plain; version=0.0.4")),
                        ("body", Json::str(coordinator.metrics.prometheus())),
                    ]));
                }
                Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("metrics", Json::str(coordinator.metrics.summary())),
                    // flat numeric counters — what the fleet router sums when
                    // aggregating metrics across workers
                    ("counters", coordinator.metrics.counters_json()),
                    // engine + KV-pool accounting (batch occupancy, queue
                    // depth, pool utilisation, aggregate decode tok/s)
                    ("engine", coordinator.metrics.engine_json()),
                    // deployment-artifact accounting (mounts, mmap loads vs
                    // lazy calibrations)
                    ("artifacts", coordinator.metrics.artifact_json()),
                    // windowed latency histograms (TTFT, inter-token, queue
                    // wait, batch forward) with honest p50/p95/p99/p999
                    ("latency", coordinator.metrics.latency_json()),
                    // live quantization-kernel gauges (the paper's metric)
                    ("kernel", coordinator.metrics.kernel.json()),
                    // SLO burn-rate report (what `repro top` panels on)
                    ("slo", coordinator.metrics.slo_json()),
                ]))
            }
            "slo" => Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("slo", coordinator.metrics.slo_json()),
            ])),
            "metrics_reset" => {
                coordinator.metrics.reset();
                Ok(Json::obj(vec![("ok", Json::Bool(true)), ("reset", Json::Bool(true))]))
            }
            "trace" => {
                let id = req.get("id").and_then(obs::parse_trace_field).unwrap_or(0);
                let spans = coordinator.metrics.spans.for_trace(id);
                if req.get("format").and_then(|f| f.as_str()) == Some("chrome") {
                    let doc = chrome_trace_json(&spans);
                    let events = doc.get("traceEvents").cloned().unwrap_or(Json::Arr(vec![]));
                    return Ok(Json::obj(vec![
                        ("ok", Json::Bool(true)),
                        ("traceEvents", events),
                    ]));
                }
                Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("trace", Json::str(obs::trace_id_string(id))),
                    ("spans", Json::arr(spans.iter().map(|s| s.json()).collect())),
                ]))
            }
            other => Err(anyhow!("unknown cmd '{other}'")),
        };
    }

    let eval_req = parse_request(&req)?;
    let trace = eval_req.trace;
    match eval_req.kind {
        RequestKind::Generate { .. } => {
            let prompt_tokens = eval_req.tokens.len();
            let resp = coordinator.submit(eval_req)?.wait()?;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                (
                    "generated",
                    Json::arr(resp.generated.iter().map(|&t| Json::num(t as f64)).collect()),
                ),
                ("prompt_tokens", Json::num(prompt_tokens as f64)),
                ("aux", Json::num(resp.aux as f64)),
            ];
            if trace != 0 {
                fields.push(("trace", Json::str(obs::trace_id_string(trace))));
            }
            Ok(Json::obj(fields))
        }
        RequestKind::Score => {
            let resp = coordinator.submit(eval_req)?.wait()?;
            let mean =
                resp.nll.iter().map(|&v| v as f64).sum::<f64>() / resp.nll.len().max(1) as f64;
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("nll", Json::arr(resp.nll.iter().map(|&v| Json::num(v as f64)).collect())),
                ("ppl", Json::num(mean.exp())),
                ("aux", Json::num(resp.aux as f64)),
            ];
            if trace != 0 {
                fields.push(("trace", Json::str(obs::trace_id_string(trace))));
            }
            Ok(Json::obj(fields))
        }
    }
}
