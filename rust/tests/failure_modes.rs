//! Failure-mode tests: corrupt artifacts, malformed manifests, truncated
//! weight files, JSON round-trips — and the fault-tolerant serving tier
//! (a real supervised worker fleet with deterministic fault injection).
//! None of these require `make artifacts`.

use std::path::PathBuf;

use crossquant::eval::harness::{Row, Table};
use crossquant::model::weights::{Manifest, Weights};
use crossquant::runtime::ArtifactStore;
use crossquant::util::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "cq-fail-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH).unwrap().as_nanos()
    ));
    std::fs::create_dir_all(&p).unwrap();
    p
}

const GOOD_MANIFEST: &str = r#"{
  "config": {"vocab": 8, "d_model": 4, "n_layers": 1, "n_heads": 2,
             "d_ff": 8, "seq_len": 6, "eval_batch": 2},
  "params": [{"name": "tok_emb", "shape": [8, 4], "offset": 0, "size": 32}],
  "total_params": 32
}"#;

#[test]
fn manifest_parses_minimal() {
    let m = Manifest::parse(GOOD_MANIFEST).unwrap();
    assert_eq!(m.config.vocab, 8);
    assert_eq!(m.params.len(), 1);
    assert!(m.train.is_none());
}

#[test]
fn manifest_rejects_missing_config() {
    assert!(Manifest::parse(r#"{"params": [], "total_params": 0}"#).is_err());
}

#[test]
fn manifest_rejects_non_json() {
    assert!(Manifest::parse("HloModule not json").is_err());
    assert!(Manifest::parse("").is_err());
}

#[test]
fn weights_load_rejects_truncated_bin() {
    let dir = tmp_dir("trunc");
    std::fs::write(dir.join("manifest.json"), GOOD_MANIFEST).unwrap();
    std::fs::write(dir.join("weights.bin"), vec![0u8; 16]).unwrap(); // needs 128
    let err = Weights::load(&dir).unwrap_err();
    assert!(format!("{err}").contains("weights.bin"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn weights_load_missing_files() {
    let dir = tmp_dir("missing");
    assert!(Weights::load(&dir).is_err()); // no manifest
    std::fs::write(dir.join("manifest.json"), GOOD_MANIFEST).unwrap();
    assert!(Weights::load(&dir).is_err()); // no weights.bin
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn artifact_store_validate_reports_missing_hlo() {
    let dir = tmp_dir("nohlo");
    std::fs::write(dir.join("manifest.json"), GOOD_MANIFEST).unwrap();
    let store = ArtifactStore::discover(Some(&dir)).unwrap();
    assert!(store.available().is_empty());
    let err = store.validate().unwrap_err();
    assert!(format!("{err}").contains("make artifacts"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn artifact_store_discover_needs_manifest() {
    let dir = tmp_dir("empty");
    assert!(ArtifactStore::discover(Some(&dir)).is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn table_json_roundtrips_through_parser() {
    let mut t = Table::new("Table 2 — perplexity", vec!["Wiki2", "C4"]);
    t.push(Row::new("FP16", "W16A16", vec![5.47, 7.52]));
    t.push(Row::new("Per-token", "W4A4", vec![2e4, f64::NAN]));
    let json = t.to_json();
    let re = Json::parse(&json.render_pretty());
    // NaN is not valid JSON — the writer must have produced something the
    // parser accepts or the render should be fixed; assert it's handled.
    match re {
        Ok(v) => {
            assert_eq!(v.get("title").unwrap().as_str(), Some("Table 2 — perplexity"));
            assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 2);
        }
        Err(e) => panic!("table JSON must be parseable: {e}"),
    }
}

// ─────────────────────────────────────────────────────────────────────
// Fleet suite: real `repro serve --worker` processes over a tiny .cqa
// artifact, supervised by Fleet and fronted by Router. Faults are
// injected deterministically via per-worker CROSSQUANT_FAULT plans.

mod fleet_suite {
    use super::tmp_dir;
    use std::io::{BufRead, BufReader, Write};
    use std::net::{SocketAddr, TcpListener, TcpStream};
    use std::path::{Path, PathBuf};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use crossquant::coordinator::{Fleet, FleetConfig, FleetMetrics, Router, RouterConfig};
    use crossquant::corpus::CorpusGen;
    use crossquant::model::quantized::quantize_to_artifact;
    use crossquant::model::weights::synthetic_weights;
    use crossquant::model::ModelConfig;
    use crossquant::quant::registry::{SchemeId, StaticSpec};
    use crossquant::quant::Bits;
    use crossquant::util::Json;

    /// Build a minimal .cqa artifact every worker in a fleet mmaps.
    fn tiny_artifact(dir: &Path) -> PathBuf {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            seq_len: 32,
            eval_batch: 2,
        };
        let weights = synthetic_weights(cfg, 0xFEE7);
        let mut gen = CorpusGen::new(cfg.vocab, 0x5CA1E);
        let calib: Vec<Vec<u32>> = (0..2).map(|_| gen.sequence(cfg.seq_len)).collect();
        let spec = StaticSpec::new(SchemeId::CrossQuantStatic, 0.15, 0);
        let path = dir.join("model.cqa");
        quantize_to_artifact(&weights, Bits::Int8, Bits::Int8, &spec, &calib, &path).unwrap();
        path
    }

    /// Start a fleet of worker processes (test-tuned supervision
    /// timings) plus a router, and wait until every worker is ready.
    fn start_tier(
        num_workers: usize,
        artifact: &Path,
        per_worker_env: Vec<Vec<(String, String)>>,
        tune: impl FnOnce(&mut FleetConfig),
    ) -> (Arc<Fleet>, Router) {
        let mut cfg = FleetConfig {
            num_workers,
            worker_cmd: PathBuf::from(env!("CARGO_BIN_EXE_repro")),
            worker_args: vec![
                "serve".to_string(),
                "--worker".to_string(),
                "--addr".to_string(),
                "127.0.0.1:0".to_string(),
                "--artifact".to_string(),
                artifact.display().to_string(),
            ],
            per_worker_env,
            heartbeat_interval: Duration::from_millis(50),
            heartbeat_timeout: Duration::from_millis(500),
            initial_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_millis(400),
            ..FleetConfig::default()
        };
        tune(&mut cfg);
        let fleet = Arc::new(Fleet::start(cfg, Arc::new(FleetMetrics::new())).unwrap());
        fleet.wait_ready(Duration::from_secs(60)).unwrap();
        let router = Router::new(
            fleet.clone(),
            RouterConfig {
                default_deadline: Duration::from_secs(20),
                max_retries: 3,
                retry_poll: Duration::from_millis(20),
                ..RouterConfig::default()
            },
        );
        (fleet, router)
    }

    /// Serve the router on an ephemeral port from a background thread.
    fn start_router(router: &Router) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let r = router.clone();
        std::thread::spawn(move || {
            let _ = r.serve(listener);
        });
        addr
    }

    /// One request → one JSON response line through the router.
    fn request(addr: SocketAddr, line: &str) -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        s.write_all(line.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(s);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(&resp).unwrap_or_else(|e| panic!("unparseable response {resp:?}: {e}"))
    }

    fn score_line(seed: usize) -> String {
        let tokens: Vec<String> = (0..8).map(|i| ((seed * 7 + i * 3) % 64).to_string()).collect();
        format!(
            "{{\"tokens\": [{}], \"scheme\": \"crossquant-static\", \"alpha\": 0.15}}",
            tokens.join(", ")
        )
    }

    fn generate_line(seed: usize) -> String {
        format!(
            "{{\"tokens\": [{}, {}], \"scheme\": \"crossquant-static\", \"alpha\": 0.15, \
             \"max_new_tokens\": 3}}",
            seed % 64,
            (seed * 5) % 64
        )
    }

    fn is_ok(resp: &Json) -> bool {
        resp.get("ok") == Some(&Json::Bool(true))
    }

    fn wait_until(timeout: Duration, what: &str, mut cond: impl FnMut() -> bool) {
        let deadline = Instant::now() + timeout;
        while !cond() {
            assert!(Instant::now() < deadline, "timed out waiting for {what}");
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// The headline acceptance scenario: concurrent mixed load on a
    /// 4-worker fleet, `kill -9` one worker mid-stream of requests —
    /// clients must see zero failures (transparent failover) and the
    /// victim must rejoin the fleet within its restart backoff.
    #[test]
    fn kill9_under_load_is_invisible_to_clients_and_worker_rejoins() {
        let dir = tmp_dir("fleet-kill9");
        let artifact = tiny_artifact(&dir);
        let (fleet, router) = start_tier(4, &artifact, Vec::new(), |_| {});
        let addr = start_router(&router);

        // clients loop until told to stop, so the load provably spans
        // the kill and the restart window
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let handles: Vec<_> = (0..4)
            .map(|c| {
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let (mut i, mut done, mut failures) = (0usize, 0usize, Vec::new());
                    while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                        let line = if (c + i) % 3 == 0 {
                            generate_line(c * 100 + i)
                        } else {
                            score_line(c * 100 + i)
                        };
                        let resp = request(addr, &line);
                        if !is_ok(&resp) {
                            failures.push(resp.render());
                        }
                        i += 1;
                        done += 1;
                    }
                    (done, failures)
                })
            })
            .collect();

        // let the load ramp, then hard-kill one worker under it
        std::thread::sleep(Duration::from_millis(100));
        let victim = fleet.workers()[0].pid().expect("worker 0 has a pid");
        let killed = std::process::Command::new("kill")
            .args(["-9", &victim.to_string()])
            .status()
            .unwrap();
        assert!(killed.success(), "kill -9 {victim} failed");

        // keep the load running until the victim has rejoined the fleet
        wait_until(Duration::from_secs(30), "worker 0 to rejoin", || {
            fleet.workers()[0].is_healthy()
        });
        std::thread::sleep(Duration::from_millis(200));
        stop.store(true, std::sync::atomic::Ordering::SeqCst);

        let mut total = 0usize;
        for h in handles {
            let (done, failures) = h.join().unwrap();
            total += done;
            assert!(failures.is_empty(), "client-visible failures after kill -9: {failures:?}");
        }
        assert!(total > 0, "clients made no requests");
        assert!(fleet.workers()[0].restarts() >= 1);
        assert!(fleet.metrics().worker_crashes.load(std::sync::atomic::Ordering::SeqCst) >= 1);
        fleet.shutdown();
        std::fs::remove_dir_all(dir).ok();
    }

    /// A worker stalled past the request deadline yields a structured,
    /// retryable deadline error — not a hang, not a panic.
    #[test]
    fn deadline_exceeded_returns_structured_retryable_error() {
        let dir = tmp_dir("fleet-deadline");
        let artifact = tiny_artifact(&dir);
        // every data request on the only worker stalls for 2 s;
        // heartbeats are never perturbed, so it stays "healthy"
        let faults =
            vec![vec![("CROSSQUANT_FAULT".to_string(), "latency:ms=2000,every=1".to_string())]];
        let (fleet, router) = start_tier(1, &artifact, faults, |_| {});
        let addr = start_router(&router);

        let line = format!(
            "{{\"deadline_ms\": 300, {}",
            score_line(1).strip_prefix('{').unwrap()
        );
        let resp = request(addr, &line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
        assert_eq!(resp.get("retryable"), Some(&Json::Bool(true)), "{resp:?}");
        let err = resp.get("error").and_then(|e| e.as_str()).unwrap_or_default();
        assert!(err.contains("deadline"), "unexpected error text: {err}");

        let metrics = request(addr, "{\"cmd\": \"metrics\"}");
        let exceeded = metrics
            .get("router")
            .and_then(|r| r.get("deadline_exceeded"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        assert!(exceeded >= 1.0, "{metrics:?}");
        fleet.shutdown();
        std::fs::remove_dir_all(dir).ok();
    }

    /// A worker that aborts on every request crash-loops; the breaker
    /// must trip (stopping futile restarts) while every client response
    /// stays a structured error.
    #[test]
    fn crash_loop_trips_circuit_breaker() {
        let dir = tmp_dir("fleet-breaker");
        let artifact = tiny_artifact(&dir);
        let faults = vec![vec![("CROSSQUANT_FAULT".to_string(), "panic:every=1".to_string())]];
        let (fleet, router) = start_tier(1, &artifact, faults, |cfg| {
            cfg.breaker_crashes = 3;
            cfg.initial_backoff = Duration::from_millis(20);
        });
        let addr = start_router(&router);

        let deadline = Instant::now() + Duration::from_secs(60);
        while !fleet.workers()[0].breaker_open() {
            assert!(Instant::now() < deadline, "breaker never tripped");
            let line = format!(
                "{{\"deadline_ms\": 4000, {}",
                score_line(2).strip_prefix('{').unwrap()
            );
            let resp = request(addr, &line);
            // the worker aborts on every data request: never ok, always
            // a parseable structured error
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
            assert!(resp.get("error").is_some(), "{resp:?}");
        }
        assert!(fleet.metrics().breaker_trips.load(std::sync::atomic::Ordering::SeqCst) >= 1);
        // with every breaker open the tier sheds load instead of hanging
        let resp = request(addr, &score_line(3));
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("retryable"), Some(&Json::Bool(true)));
        fleet.shutdown();
        std::fs::remove_dir_all(dir).ok();
    }

    /// A worker whose responses are truncated mid-frame is treated as
    /// failed and the request transparently retries on the clean worker.
    #[test]
    fn truncated_worker_frames_fail_over_to_surviving_worker() {
        let dir = tmp_dir("fleet-trunc");
        let artifact = tiny_artifact(&dir);
        let faults = vec![
            vec![("CROSSQUANT_FAULT".to_string(), "truncate:every=1".to_string())],
            Vec::new(), // worker 1 is clean
        ];
        let (fleet, router) = start_tier(2, &artifact, faults, |_| {});
        let addr = start_router(&router);

        for i in 0..6 {
            let resp = request(addr, &score_line(i));
            assert!(is_ok(&resp), "failover should hide truncation: {resp:?}");
        }
        let retried = fleet.metrics().retried.load(std::sync::atomic::Ordering::SeqCst);
        assert!(retried >= 1, "expected at least one failover retry, saw {retried}");
        fleet.shutdown();
        std::fs::remove_dir_all(dir).ok();
    }

    /// Malformed, non-object, invalid-UTF-8 and client-truncated frames
    /// must never panic the router; it answers with structured errors
    /// and keeps serving good requests afterwards.
    #[test]
    fn malformed_and_truncated_client_frames_never_panic_router() {
        let dir = tmp_dir("fleet-fuzz");
        let artifact = tiny_artifact(&dir);
        let (fleet, router) = start_tier(1, &artifact, Vec::new(), |_| {});
        let addr = start_router(&router);

        for junk in [
            "this is not json",
            "{\"tokens\": [1, 2",     // unterminated object
            "[1, 2, 3]",              // valid JSON, not an object
            "42",                     // valid JSON scalar
            "{\"cmd\": \"no-such\"}", // unknown command
            "{\"tokens\": [1, 2, 3], \"deadline_ms\": -5}", // bad deadline
            "{}",                     // data request with no tokens
        ] {
            let resp = request(addr, junk);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{junk} → {resp:?}");
            assert!(resp.get("error").is_some(), "{junk} → {resp:?}");
        }

        // invalid UTF-8: the router closes the connection, no panic
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&[0xff, 0xfe, 0xfd, b'\n']).unwrap();
        drop(s);

        // client truncation: open, write half a frame, vanish
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"{\"tokens\": [1, ").unwrap();
        drop(s);

        // the tier still serves correct requests afterwards
        let resp = request(addr, &score_line(9));
        assert!(is_ok(&resp), "router wedged after fuzzing: {resp:?}");
        let metrics = request(addr, "{\"cmd\": \"metrics\"}");
        let malformed = metrics
            .get("router")
            .and_then(|r| r.get("malformed"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        assert!(malformed >= 3.0, "{metrics:?}");
        fleet.shutdown();
        std::fs::remove_dir_all(dir).ok();
    }

    /// Aggregated metrics: worker counters are summed across the fleet
    /// and per-worker status rows are present.
    #[test]
    fn metrics_aggregate_across_fleet() {
        let dir = tmp_dir("fleet-metrics");
        let artifact = tiny_artifact(&dir);
        let (fleet, router) = start_tier(2, &artifact, Vec::new(), |_| {});
        let addr = start_router(&router);

        for i in 0..4 {
            assert!(is_ok(&request(addr, &score_line(i))));
        }
        let m = request(addr, "{\"cmd\": \"metrics\"}");
        assert!(is_ok(&m), "{m:?}");
        let workers = m.get("workers").and_then(|w| w.as_arr()).unwrap();
        assert_eq!(workers.len(), 2);
        let completed = m
            .get("aggregate")
            .and_then(|a| a.get("completed"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        assert!(completed >= 4.0, "fleet-wide completed should sum to ≥ 4: {m:?}");
        let routed = m
            .get("router")
            .and_then(|r| r.get("requests"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        assert!(routed >= 4.0, "{m:?}");
        fleet.shutdown();
        std::fs::remove_dir_all(dir).ok();
    }
}

#[test]
fn corrupt_hlo_fails_gracefully_in_runtime() {
    let dir = tmp_dir("badhlo");
    std::fs::write(dir.join("manifest.json"), GOOD_MANIFEST).unwrap();
    std::fs::write(dir.join("lm_fp.hlo.txt"), "this is not hlo").unwrap();
    let store = ArtifactStore::discover(Some(&dir)).unwrap();
    let mut runtime = match crossquant::runtime::Runtime::new(store) {
        Ok(r) => r,
        Err(_) => return, // no PJRT in this environment — nothing to check
    };
    let err = runtime.prepare("lm_fp").unwrap_err();
    assert!(format!("{err}").contains("lm_fp"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}
