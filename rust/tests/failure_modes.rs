//! Failure-mode tests: corrupt artifacts, malformed manifests, truncated
//! weight files, and JSON round-trips. None of these require `make
//! artifacts`.

use std::path::PathBuf;

use crossquant::eval::harness::{Row, Table};
use crossquant::model::weights::{Manifest, Weights};
use crossquant::runtime::ArtifactStore;
use crossquant::util::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "cq-fail-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH).unwrap().as_nanos()
    ));
    std::fs::create_dir_all(&p).unwrap();
    p
}

const GOOD_MANIFEST: &str = r#"{
  "config": {"vocab": 8, "d_model": 4, "n_layers": 1, "n_heads": 2,
             "d_ff": 8, "seq_len": 6, "eval_batch": 2},
  "params": [{"name": "tok_emb", "shape": [8, 4], "offset": 0, "size": 32}],
  "total_params": 32
}"#;

#[test]
fn manifest_parses_minimal() {
    let m = Manifest::parse(GOOD_MANIFEST).unwrap();
    assert_eq!(m.config.vocab, 8);
    assert_eq!(m.params.len(), 1);
    assert!(m.train.is_none());
}

#[test]
fn manifest_rejects_missing_config() {
    assert!(Manifest::parse(r#"{"params": [], "total_params": 0}"#).is_err());
}

#[test]
fn manifest_rejects_non_json() {
    assert!(Manifest::parse("HloModule not json").is_err());
    assert!(Manifest::parse("").is_err());
}

#[test]
fn weights_load_rejects_truncated_bin() {
    let dir = tmp_dir("trunc");
    std::fs::write(dir.join("manifest.json"), GOOD_MANIFEST).unwrap();
    std::fs::write(dir.join("weights.bin"), vec![0u8; 16]).unwrap(); // needs 128
    let err = Weights::load(&dir).unwrap_err();
    assert!(format!("{err}").contains("weights.bin"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn weights_load_missing_files() {
    let dir = tmp_dir("missing");
    assert!(Weights::load(&dir).is_err()); // no manifest
    std::fs::write(dir.join("manifest.json"), GOOD_MANIFEST).unwrap();
    assert!(Weights::load(&dir).is_err()); // no weights.bin
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn artifact_store_validate_reports_missing_hlo() {
    let dir = tmp_dir("nohlo");
    std::fs::write(dir.join("manifest.json"), GOOD_MANIFEST).unwrap();
    let store = ArtifactStore::discover(Some(&dir)).unwrap();
    assert!(store.available().is_empty());
    let err = store.validate().unwrap_err();
    assert!(format!("{err}").contains("make artifacts"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn artifact_store_discover_needs_manifest() {
    let dir = tmp_dir("empty");
    assert!(ArtifactStore::discover(Some(&dir)).is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn table_json_roundtrips_through_parser() {
    let mut t = Table::new("Table 2 — perplexity", vec!["Wiki2", "C4"]);
    t.push(Row::new("FP16", "W16A16", vec![5.47, 7.52]));
    t.push(Row::new("Per-token", "W4A4", vec![2e4, f64::NAN]));
    let json = t.to_json();
    let re = Json::parse(&json.render_pretty());
    // NaN is not valid JSON — the writer must have produced something the
    // parser accepts or the render should be fixed; assert it's handled.
    match re {
        Ok(v) => {
            assert_eq!(v.get("title").unwrap().as_str(), Some("Table 2 — perplexity"));
            assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 2);
        }
        Err(e) => panic!("table JSON must be parseable: {e}"),
    }
}

#[test]
fn corrupt_hlo_fails_gracefully_in_runtime() {
    let dir = tmp_dir("badhlo");
    std::fs::write(dir.join("manifest.json"), GOOD_MANIFEST).unwrap();
    std::fs::write(dir.join("lm_fp.hlo.txt"), "this is not hlo").unwrap();
    let store = ArtifactStore::discover(Some(&dir)).unwrap();
    let mut runtime = match crossquant::runtime::Runtime::new(store) {
        Ok(r) => r,
        Err(_) => return, // no PJRT in this environment — nothing to check
    };
    let err = runtime.prepare("lm_fp").unwrap_err();
    assert!(format!("{err}").contains("lm_fp"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}
