//! Property tests pinning the parallel and fused engine paths bit-exact
//! against their serial scalar references (hand-rolled randomized driver —
//! the offline build has no proptest; see Cargo.toml).
//!
//! Every worker count must produce identical bytes: the row-parallel
//! primitives split work on whole-row boundaries and each row runs the
//! exact same scalar kernel, so float results cannot drift. Shapes cover
//! the awkward cases — fewer rows than workers, 1×N, N×1, and empty.

use crossquant::analysis::{
    kernel_fraction_threads, quantize_with_report, quantize_with_report_threads, KernelReport,
};
use crossquant::quant::{
    crossquant::CrossQuant, fake_quant_with_threads, per_token::PerToken, ActQuantizer, Bits,
};
use crossquant::tensor::{Matrix, SplitMix64};

const CASES: usize = 60;
const WORKER_GRID: [usize; 4] = [2, 3, 7, 16];

/// Random matrix with occasional outlier columns and exact zeros.
fn arb_matrix(rng: &mut SplitMix64) -> Matrix {
    let rows = 1 + rng.below(80);
    let cols = 1 + rng.below(80);
    let mut x = Matrix::randn(rows, cols, 1.0, rng);
    if rng.uniform() < 0.5 {
        let j = rng.below(cols);
        let scale = 10.0 + rng.uniform() as f32 * 90.0;
        for i in 0..rows {
            let v = x.get(i, j) * scale;
            x.set(i, j, v);
        }
    }
    if rng.uniform() < 0.3 {
        for _ in 0..rows * cols / 10 {
            let idx = rng.below(rows * cols);
            x.data[idx] = 0.0;
        }
    }
    x
}

/// The shapes where chunking logic can go wrong.
fn edge_shapes(rng: &mut SplitMix64) -> Vec<Matrix> {
    vec![
        Matrix::randn(1, 97, 1.0, rng),  // 1×N: one row, many workers idle
        Matrix::randn(97, 1, 1.0, rng),  // N×1: single-element rows
        Matrix::randn(3, 50, 1.0, rng),  // rows < workers
        Matrix::zeros(0, 13),            // empty: no rows
        Matrix::zeros(13, 0),            // empty: no cols
        Matrix::zeros(0, 0),             // empty: nothing at all
    ]
}

fn arb_quant(rng: &mut SplitMix64) -> CrossQuant {
    let alpha = (rng.uniform() as f32 * 100.0).round() / 100.0;
    let bits = match rng.below(3) {
        0 => Bits::Int4,
        1 => Bits::Int8,
        _ => Bits::Other(6),
    };
    CrossQuant::new(alpha, bits)
}

/// Parallel fake-quant is bit-exact with the serial reference for every
/// worker count.
#[test]
fn prop_fake_quant_parallel_bit_exact() {
    let mut rng = SplitMix64::new(0xA1);
    for case in 0..CASES {
        let x = arb_matrix(&mut rng);
        let q = arb_quant(&mut rng);
        let field = q.delta_field(&x);
        let serial = fake_quant_with_threads(&x, &field, q.qmax(), 1);
        for workers in WORKER_GRID {
            let par = fake_quant_with_threads(&x, &field, q.qmax(), workers);
            assert_eq!(par.data, serial.data, "case {case} workers {workers}");
        }
    }
}

/// Parallel kernel-fraction counts are identical to the serial scan.
#[test]
fn prop_kernel_fraction_parallel_bit_exact() {
    let mut rng = SplitMix64::new(0xA2);
    for case in 0..CASES {
        let x = arb_matrix(&mut rng);
        let q = arb_quant(&mut rng);
        let field = q.delta_field(&x);
        let serial = kernel_fraction_threads(&x, &field, 1);
        for workers in WORKER_GRID {
            let par = kernel_fraction_threads(&x, &field, workers);
            assert_eq!(par, serial, "case {case} workers {workers}");
        }
    }
}

/// The blocked parallel matmul is bit-exact with its serial reference and
/// with a naive scalar ikj triple loop (ascending-k accumulation).
#[test]
fn prop_matmul_blocked_parallel_bit_exact() {
    let mut rng = SplitMix64::new(0xA3);
    for case in 0..CASES / 3 {
        let m = 1 + rng.below(24);
        let k = 1 + rng.below(600); // exceed the 256-wide k-block
        let n = 1 + rng.below(24);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 0.2, &mut rng);

        let mut naive = Matrix::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let av = a.get(i, p);
                for j in 0..n {
                    let v = naive.get(i, j) + av * b.get(p, j);
                    naive.set(i, j, v);
                }
            }
        }

        let serial = a.matmul_threads(&b, 1);
        assert_eq!(serial.data, naive.data, "case {case}: blocked serial vs naive");
        for workers in WORKER_GRID {
            assert_eq!(
                a.matmul_threads(&b, workers).data,
                naive.data,
                "case {case} workers {workers}"
            );
        }
    }
}

/// Fused quantize_with_report == separate fake_quant + KernelReport:
/// output matrix and integer counts exact, mean statistics within f64
/// summation-regrouping tolerance.
#[test]
fn prop_fused_equals_separate() {
    let mut rng = SplitMix64::new(0xA4);
    for case in 0..CASES {
        let x = arb_matrix(&mut rng);
        let q = arb_quant(&mut rng);
        let (fused_q, fused_r) = quantize_with_report(&x, &q);
        assert_eq!(fused_q.data, q.fake_quant(&x).data, "case {case}: output");
        let sep = KernelReport::compute(&x, &q);
        assert_eq!(fused_r.count, sep.count, "case {case}: count");
        assert_eq!(fused_r.total, sep.total, "case {case}: total");
        assert_eq!(fused_r.fraction, sep.fraction, "case {case}: fraction");
        let tol = 1e-6 * fused_r.mean_abs_kernel.abs().max(1.0);
        assert!((fused_r.mean_abs_kernel - sep.mean_abs_kernel).abs() <= tol, "case {case}");
        let tol = 1e-6 * fused_r.mean_abs_rest.abs().max(1.0);
        assert!((fused_r.mean_abs_rest - sep.mean_abs_rest).abs() <= tol, "case {case}");
    }
}

/// Per-token fused path agrees too (PerRow field variant).
#[test]
fn prop_fused_per_token_counts() {
    let mut rng = SplitMix64::new(0xA5);
    for _ in 0..CASES / 2 {
        let x = arb_matrix(&mut rng);
        let q = PerToken::new(Bits::Int8);
        for workers in [1usize, 2, 16] {
            let (out, r) = quantize_with_report_threads(&x, &q, workers);
            assert_eq!(out.data, q.fake_quant(&x).data);
            assert_eq!(r.count, KernelReport::compute(&x, &q).count);
        }
    }
}

/// Every engine entry point survives the degenerate shapes, with rows <
/// workers and empty matrices included, and stays consistent with the
/// serial path there.
#[test]
fn edge_shapes_consistent_across_worker_counts() {
    let mut rng = SplitMix64::new(0xA6);
    for x in edge_shapes(&mut rng) {
        let q = CrossQuant::new(0.15, Bits::Int8);
        let field = q.delta_field(&x);
        let fq1 = fake_quant_with_threads(&x, &field, q.qmax(), 1);
        let kf1 = kernel_fraction_threads(&x, &field, 1);
        let cam1 = x.col_abs_max_threads(1);
        for workers in WORKER_GRID {
            assert_eq!(fake_quant_with_threads(&x, &field, q.qmax(), workers).data, fq1.data);
            assert_eq!(kernel_fraction_threads(&x, &field, workers), kf1);
            assert_eq!(x.col_abs_max_threads(workers), cam1);
            let (out, r) = quantize_with_report_threads(&x, &q, workers);
            assert_eq!(out.data, fq1.data);
            assert_eq!(r.total, x.len());
        }
        // matmul against a compatible random rhs (cols can be zero)
        let rhs = Matrix::randn(x.cols, 5, 1.0, &mut rng);
        let mm1 = x.matmul_threads(&rhs, 1);
        for workers in WORKER_GRID {
            assert_eq!(x.matmul_threads(&rhs, workers).data, mm1.data);
        }
    }
}

/// The integer qlinear CrossQuant path (with its parallel per-batch
/// weight-rescale pass) stays deterministic and α=1-consistent.
#[test]
fn qlinear_crossquant_deterministic_across_runs() {
    use crossquant::quant::qlinear::QuantizedLinear;
    let mut rng = SplitMix64::new(0xA7);
    let x = Matrix::randn(64, 48, 1.0, &mut rng);
    let w = Matrix::randn(48, 32, 0.1, &mut rng);
    let lin = QuantizedLinear::from_weight(&w, Bits::Int8);
    let a = lin.forward_crossquant(&x, 0.15, Bits::Int8);
    let b = lin.forward_crossquant(&x, 0.15, Bits::Int8);
    assert_eq!(a.data, b.data, "parallel rescale must be deterministic");
}

/// NaN handling end to end: abs-max propagates NaN instead of absorbing
/// it, and the debug-build delta_field guard turns a corrupt activation
/// matrix into a loud panic instead of quietly wrong kernel numbers.
#[test]
fn nan_propagates_through_abs_max() {
    let mut x = Matrix::zeros(3, 4);
    x.set(1, 2, f32::NAN);
    x.set(0, 0, 5.0);
    let t = x.row_abs_max();
    assert_eq!(t[0], 5.0);
    assert!(t[1].is_nan());
    let c = x.col_abs_max();
    assert_eq!(c[0], 5.0);
    assert!(c[2].is_nan());
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "non-finite activation")]
fn delta_field_rejects_nan_in_debug_builds() {
    let mut x = Matrix::zeros(4, 4);
    x.set(2, 2, f32::NAN);
    let _ = CrossQuant::new(0.15, Bits::Int8).delta_field(&x);
}

#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "non-finite activation")]
fn delta_field_rejects_inf_in_debug_builds() {
    let mut x = Matrix::zeros(4, 4);
    x.set(0, 3, f32::INFINITY);
    let _ = PerToken::new(Bits::Int8).delta_field(&x);
}
