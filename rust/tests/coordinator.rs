//! Coordinator integration tests: batching behaviour, ordering,
//! backpressure, and failure injection (broken artifacts, unknown weight
//! sets, out-of-range requests). The failure tests run without artifacts;
//! the happy-path tests skip when `make artifacts` hasn't run.

use std::time::Duration;

use crossquant::coordinator::scheduler::{CoordinatorConfig, EvalCoordinator, EvalRequest};
use crossquant::coordinator::ActScheme;
use crossquant::corpus::CorpusGen;
use crossquant::model::ModelConfig;
use crossquant::runtime::ArtifactStore;

fn real_store() -> Option<(ArtifactStore, crossquant::model::weights::Weights)> {
    let store = ArtifactStore::discover(None).ok()?;
    store.validate().ok()?;
    let w = store.load_weights().ok()?;
    Some((store, w))
}

/// A store pointing at a directory with a valid manifest but missing HLO
/// files — the executor must fail requests gracefully, not crash.
fn broken_store() -> (ArtifactStore, tempdir::TempDir) {
    let dir = tempdir::TempDir::new("cq-broken");
    // minimal-but-parseable manifest
    let manifest = r#"{
        "config": {"vocab": 64, "d_model": 16, "n_layers": 1, "n_heads": 2,
                   "d_ff": 32, "seq_len": 12, "eval_batch": 2},
        "params": [], "total_params": 0
    }"#;
    std::fs::write(dir.path().join("manifest.json"), manifest).unwrap();
    (ArtifactStore { dir: dir.path().to_path_buf() }, dir)
}

/// std has no tempdir; 8 lines suffice.
mod tempdir {
    pub struct TempDir(std::path::PathBuf);

    impl TempDir {
        pub fn new(prefix: &str) -> TempDir {
            let p = std::env::temp_dir().join(format!(
                "{prefix}-{}-{:?}",
                std::process::id(),
                std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .unwrap()
                    .as_nanos()
            ));
            std::fs::create_dir_all(&p).unwrap();
            TempDir(p)
        }

        pub fn path(&self) -> &std::path::Path {
            &self.0
        }
    }

    impl Drop for TempDir {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }
}

#[test]
fn failure_injection_missing_artifact() {
    let (store, _guard) = broken_store();
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    };
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w".into(), vec![0.0; 4])],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 8,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    let handle = coordinator
        .submit(EvalRequest::score(vec![1, 2, 3], ActScheme::Fp, "w"))
        .expect("submit should succeed");
    let err = handle.wait().expect_err("execution must fail");
    assert!(format!("{err}").contains("failed"), "unexpected error: {err}");
    assert!(coordinator.metrics.failed.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}

#[test]
fn native_executor_serves_static_scale_scheme() {
    let (store, _guard) = broken_store();
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    };
    let weights = crossquant::model::weights::synthetic_weights(cfg, 9);
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 8,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    let mut gen = CorpusGen::new(cfg.vocab, 4);
    let tokens = gen.sequence(cfg.seq_len);
    let submit = |toks: Vec<u32>| {
        coordinator
            .submit(EvalRequest::score(
                toks,
                ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 },
                "w",
            ))
            .unwrap()
    };
    // the executor serves the static scheme through the native integer
    // model on every build (PJRT-linked or not) — this must succeed
    let r = submit(tokens.clone())
        .wait_timeout(Duration::from_secs(120))
        .expect("static scheme must be served natively");
    assert_eq!(r.nll.len(), cfg.seq_len - 1);
    assert!(r.nll.iter().all(|v| v.is_finite()));
    assert_eq!(r.aux, 0.0);
    // the calibrated model is cached per (weight set, α): a repeat of
    // the same request is deterministic
    let again = submit(tokens).wait_timeout(Duration::from_secs(120)).unwrap();
    assert_eq!(again.nll, r.nll);
    // malformed static requests fail the request, not the process: the
    // native path serves the INT8 grid only
    let bad = coordinator
        .submit(EvalRequest::score(
            gen.sequence(cfg.seq_len),
            ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 50.0 },
            "w",
        ))
        .unwrap();
    assert!(bad.wait_timeout(Duration::from_secs(120)).is_err());
}

#[test]
fn generation_round_trips_for_every_scheme() {
    let (store, _guard) = broken_store();
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    };
    let weights = crossquant::model::weights::synthetic_weights(cfg, 17);
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 8,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    let mut gen = CorpusGen::new(cfg.vocab, 5);
    for scheme in [
        ActScheme::Fp,
        ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 },
        ActScheme::RemoveKernel { theta: 0.01 },
        ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 },
    ] {
        let prompt = gen.sequence(4);
        let submit = |p: Vec<u32>| {
            coordinator.submit(EvalRequest::generate(p, scheme, "w", 6)).unwrap()
        };
        let r = submit(prompt.clone())
            .wait_timeout(Duration::from_secs(120))
            .unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
        assert_eq!(r.generated.len(), 6, "{scheme:?}");
        assert!(r.generated.iter().all(|&t| (t as usize) < cfg.vocab));
        assert!(r.nll.is_empty(), "generation responses carry no NLL");
        // greedy decode is deterministic per scheme
        let again = submit(prompt).wait_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(again.generated, r.generated, "{scheme:?}");
    }
}

#[test]
fn generation_context_overflow_is_a_structured_submit_error() {
    let (store, _guard) = broken_store();
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    };
    let coordinator = EvalCoordinator::start(store, cfg, vec![], CoordinatorConfig::default());
    // prompt 8 + 5 new tokens > n_ctx 12 ⇒ Err at submit, not a panic
    let err = coordinator
        .submit(EvalRequest::generate(vec![1; 8], ActScheme::Fp, "w", 5))
        .expect_err("overflow must be rejected");
    assert!(format!("{err}").contains("exceeds model context"), "unexpected error: {err}");
    // empty prompt and zero budget are rejected too
    assert!(coordinator.submit(EvalRequest::generate(vec![], ActScheme::Fp, "w", 3)).is_err());
    assert!(coordinator.submit(EvalRequest::generate(vec![1; 4], ActScheme::Fp, "w", 0)).is_err());
}

#[test]
fn rejects_out_of_range_sequences() {
    let (store, _guard) = broken_store();
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    };
    let coordinator =
        EvalCoordinator::start(store, cfg, vec![], CoordinatorConfig::default());
    // too short
    assert!(coordinator
        .submit(EvalRequest::score(vec![1], ActScheme::Fp, "w"))
        .is_err());
    // too long
    assert!(coordinator
        .submit(EvalRequest::score(vec![0; 13], ActScheme::Fp, "w"))
        .is_err());
}

#[test]
fn unknown_weight_set_fails_request_not_process() {
    let Some((store, weights)) = real_store() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = weights.config;
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("good".into(), weights.flat.clone())],
        CoordinatorConfig::default(),
    );
    let mut gen = CorpusGen::new(cfg.vocab, 1);
    let bad = coordinator
        .submit(EvalRequest::score(gen.sequence(cfg.seq_len), ActScheme::Fp, "nope"))
        .unwrap();
    assert!(bad.wait().is_err());
    // the coordinator keeps serving afterwards
    let good = coordinator
        .submit(EvalRequest::score(gen.sequence(cfg.seq_len), ActScheme::Fp, "good"))
        .unwrap();
    let resp = good.wait().unwrap();
    assert_eq!(resp.nll.len(), cfg.seq_len - 1);
}

#[test]
fn batches_fill_and_results_map_back() {
    let Some((store, weights)) = real_store() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = weights.config;
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: cfg.eval_batch,
            max_batch_delay: Duration::from_millis(3),
            max_queue: 64,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    let mut gen = CorpusGen::new(cfg.vocab, 2);
    // distinct lengths so each response is attributable to its request
    let lens: Vec<usize> = (0..cfg.eval_batch * 2).map(|i| cfg.seq_len - (i % 4)).collect();
    let handles: Vec<_> = lens
        .iter()
        .map(|&l| {
            coordinator
                .submit(EvalRequest::score(
                    gen.sequence(l),
                    ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 },
                    "w",
                ))
                .unwrap()
        })
        .collect();
    for (h, &l) in handles.into_iter().zip(&lens) {
        let r = h.wait().unwrap();
        assert_eq!(r.nll.len(), l - 1, "length-specific response mapping");
        assert!(r.aux > 0.0 && r.aux < 1.0);
    }
    let m = &coordinator.metrics;
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(m.completed.load(Relaxed), (cfg.eval_batch * 2) as u64);
    assert!(m.mean_batch_size() > 1.0, "batching should aggregate requests");
}

#[test]
fn partial_batch_flushes_on_deadline() {
    let Some((store, weights)) = real_store() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = weights.config;
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: cfg.eval_batch,
            max_batch_delay: Duration::from_millis(5),
            max_queue: 8,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    let mut gen = CorpusGen::new(cfg.vocab, 3);
    // a single request can never fill the batch — only the deadline flushes it
    let h = coordinator
        .submit(EvalRequest::score(gen.sequence(cfg.seq_len), ActScheme::Fp, "w"))
        .unwrap();
    let r = h.wait_timeout(Duration::from_secs(120)).unwrap();
    assert_eq!(r.nll.len(), cfg.seq_len - 1);
}
