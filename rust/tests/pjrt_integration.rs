//! Integration tests over the PJRT runtime: the AOT artifacts must agree
//! with the native rust implementations on identical inputs — the contract
//! that makes the fast native sweeps trustworthy stand-ins for the
//! three-layer path.
//!
//! All tests skip (pass trivially with a notice) when `make artifacts`
//! has not been run; CI runs them after the artifact build.

use crossquant::corpus::CorpusGen;
use crossquant::model::{IdentitySite, NativeModel, QuantSite};
use crossquant::quant::{crossquant::CrossQuant, per_token::PerToken, ActQuantizer, Bits};
use crossquant::runtime::literal::{
    literal_to_matrix, literal_to_scalar, literal_to_vec, matrix_literal, scalar_literal,
    tokens_literal, vec_literal,
};
use crossquant::runtime::{ArtifactStore, Runtime};
use crossquant::tensor::{Matrix, SplitMix64};

fn setup() -> Option<(Runtime, crossquant::model::weights::Weights)> {
    let store = ArtifactStore::discover(None).ok()?;
    store.validate().ok()?;
    let weights = store.load_weights().ok()?;
    let runtime = Runtime::new(store).ok()?;
    Some((runtime, weights))
}

macro_rules! require_artifacts {
    () => {
        match setup() {
            Some(x) => x,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn lm_fp_matches_native_forward() {
    let (mut runtime, weights) = require_artifacts!();
    let cfg = weights.config;
    let model = NativeModel::new(weights.clone());

    let mut gen = CorpusGen::new(cfg.vocab, 0xABC);
    let rows = gen.batch(cfg.eval_batch, cfg.seq_len);
    let tokens = tokens_literal(&rows, cfg.seq_len, 0).unwrap();
    let w = vec_literal(&weights.flat);
    let out = runtime.execute("lm_fp", &[tokens, w]).unwrap();
    let nll = literal_to_vec(&out[0]).unwrap();

    let per_row = cfg.seq_len - 1;
    for (b, row_tokens) in rows.iter().enumerate() {
        let native = model.forward_nll(row_tokens, &mut IdentitySite).unwrap();
        for (i, &n) in native.iter().enumerate() {
            let p = nll[b * per_row + i];
            assert!(
                (n - p).abs() < 2e-3 * n.abs().max(1.0),
                "batch {b} pos {i}: native {n} pjrt {p}"
            );
        }
    }
}

#[test]
fn lm_aq_alpha_one_matches_native_per_token() {
    let (mut runtime, weights) = require_artifacts!();
    let cfg = weights.config;
    let model = NativeModel::new(weights.clone());

    let mut gen = CorpusGen::new(cfg.vocab, 0xDEF);
    let rows = gen.batch(cfg.eval_batch, cfg.seq_len);
    let tokens = tokens_literal(&rows, cfg.seq_len, 0).unwrap();
    let w = vec_literal(&weights.flat);
    let out = runtime
        .execute("lm_aq", &[tokens, w, scalar_literal(1.0), scalar_literal(127.0)])
        .unwrap();
    let nll = literal_to_vec(&out[0]).unwrap();
    let kfrac = literal_to_scalar(&out[1]).unwrap();

    let per_row = cfg.seq_len - 1;
    let mut site = QuantSite::new(PerToken::new(Bits::Int8));
    let mut max_rel = 0.0f32;
    for (b, row_tokens) in rows.iter().enumerate() {
        let native = model.forward_nll(row_tokens, &mut site).unwrap();
        for (i, &n) in native.iter().enumerate() {
            let p = nll[b * per_row + i];
            max_rel = max_rel.max((n - p).abs() / n.abs().max(1.0));
        }
    }
    // quantization boundaries can flip under 1-ulp scale differences, so
    // the tolerance is looser than the FP path but still tight in ppl terms
    assert!(max_rel < 0.05, "max relative nll deviation {max_rel}");
    assert!(kfrac > 0.0 && kfrac < 1.0, "kernel fraction {kfrac}");
}

#[test]
fn lm_aq_kernel_fraction_tracks_alpha() {
    let (mut runtime, weights) = require_artifacts!();
    let cfg = weights.config;
    let mut gen = CorpusGen::new(cfg.vocab, 0x123);
    let rows = gen.batch(cfg.eval_batch, cfg.seq_len);
    let tokens = tokens_literal(&rows, cfg.seq_len, 0).unwrap();
    let w = vec_literal(&weights.flat);

    let kfrac_at = |runtime: &mut Runtime, alpha: f32| {
        let out = runtime
            .execute(
                "lm_aq",
                &[tokens.clone(), w.clone(), scalar_literal(alpha), scalar_literal(127.0)],
            )
            .unwrap();
        literal_to_scalar(&out[1]).unwrap()
    };
    let k15 = kfrac_at(&mut runtime, 0.15);
    let k100 = kfrac_at(&mut runtime, 1.0);
    assert!(k15 < k100, "crossquant kernel {k15} should undercut per-token {k100}");
}

#[test]
fn lm_rk_reports_removed_fraction() {
    let (mut runtime, weights) = require_artifacts!();
    let cfg = weights.config;
    let mut gen = CorpusGen::new(cfg.vocab, 0x55);
    let rows = gen.batch(cfg.eval_batch, cfg.seq_len);
    let tokens = tokens_literal(&rows, cfg.seq_len, 0).unwrap();
    let w = vec_literal(&weights.flat);

    let out = runtime.execute("lm_rk", &[tokens.clone(), w.clone(), scalar_literal(0.0)]).unwrap();
    assert!(literal_to_scalar(&out[1]).unwrap() == 0.0);
    let out = runtime.execute("lm_rk", &[tokens, w, scalar_literal(0.02)]).unwrap();
    let frac = literal_to_scalar(&out[1]).unwrap();
    assert!(frac > 0.0 && frac < 0.9, "removed fraction {frac}");
}

#[test]
fn quant_ops_matches_rust_quantizer() {
    let (mut runtime, _) = require_artifacts!();
    // artifact shape is fixed at 512×256 (aot.py QT×QI)
    let mut rng = SplitMix64::new(77);
    let x = Matrix::randn(512, 256, 1.0, &mut rng);
    let out = runtime
        .execute(
            "quant_ops",
            &[matrix_literal(&x).unwrap(), scalar_literal(0.15), scalar_literal(127.0)],
        )
        .unwrap();
    let xq = literal_to_matrix(&out[0], 512, 256).unwrap();
    let kfrac = literal_to_scalar(&out[1]).unwrap();
    let t = literal_to_vec(&out[2]).unwrap();
    let c = literal_to_vec(&out[3]).unwrap();

    let quant = CrossQuant::new(0.15, Bits::Int8);
    let native = quant.fake_quant(&x);
    let mut max_abs = 0.0f32;
    for (a, b) in xq.data.iter().zip(&native.data) {
        max_abs = max_abs.max((a - b).abs());
    }
    assert!(max_abs < 1e-4, "pallas vs rust fake-quant deviation {max_abs}");

    let native_k = crossquant::analysis::kernel_fraction(&x, &quant.delta_field(&x));
    assert!((kfrac - native_k).abs() < 5e-3, "kfrac pjrt {kfrac} rust {native_k}");

    let tn = x.row_abs_max();
    let cn = x.col_abs_max();
    for (a, b) in t.iter().zip(&tn) {
        assert!((a - b).abs() < 1e-6);
    }
    for (a, b) in c.iter().zip(&cn) {
        assert!((a - b).abs() < 1e-6);
    }
}

#[test]
fn qmatmul_close_to_fp_product() {
    let (mut runtime, _) = require_artifacts!();
    let mut rng = SplitMix64::new(88);
    let x = Matrix::randn(512, 256, 1.0, &mut rng);
    let wm = Matrix::randn(256, 128, 0.05, &mut rng);
    let out = runtime
        .execute(
            "qmatmul",
            &[
                matrix_literal(&x).unwrap(),
                matrix_literal(&wm).unwrap(),
                scalar_literal(0.15),
                scalar_literal(127.0),
            ],
        )
        .unwrap();
    let y = literal_to_matrix(&out[0], 512, 128).unwrap();
    let fp = x.matmul(&wm);
    let rel = y.distance(&fp) / fp.frobenius();
    assert!(rel < 0.02, "INT8 pallas matmul vs FP relative error {rel}");
}

#[test]
fn executable_cache_compiles_once() {
    let (mut runtime, weights) = require_artifacts!();
    let cfg = weights.config;
    let mut gen = CorpusGen::new(cfg.vocab, 0x9);
    let rows = gen.batch(cfg.eval_batch, cfg.seq_len);
    let tokens = tokens_literal(&rows, cfg.seq_len, 0).unwrap();
    let w = vec_literal(&weights.flat);
    for _ in 0..3 {
        runtime.execute("lm_fp", &[tokens.clone(), w.clone()]).unwrap();
    }
    assert_eq!(runtime.compiles, 1);
    assert_eq!(runtime.executions, 3);
    assert_eq!(runtime.cached(), 1);
}

#[test]
fn integer_path_tracks_fake_quant_on_trained_model() {
    let (_, weights) = require_artifacts!();
    use crossquant::model::quantized::{quantize_weights, WeightScheme};
    use crossquant::model::{QuantPath, QuantizedModel};
    let cfg = weights.config;
    let mut gen = CorpusGen::new(cfg.vocab, 0x1417);
    let seq = gen.sequence(cfg.seq_len);

    // fake-quant protocol (the tables' path)
    let mut wq = weights.clone();
    quantize_weights(&mut wq, WeightScheme::PerChannel(Bits::Int8)).unwrap();
    let fake = NativeModel::new(wq);
    let mut site = QuantSite::new(CrossQuant::new(0.15, Bits::Int8));
    let nll_fake = fake.forward_nll(&seq, &mut site).unwrap();

    // integer deployment path
    let qm = QuantizedModel::new(
        &weights,
        Bits::Int8,
        Bits::Int8,
        QuantPath::CrossQuant { alpha: 0.15 },
    )
    .unwrap();
    let nll_int = qm.forward_nll(&seq).unwrap();

    let mean_fake: f32 = nll_fake.iter().sum::<f32>() / nll_fake.len() as f32;
    let mean_int: f32 = nll_int.iter().sum::<f32>() / nll_int.len() as f32;
    assert!(
        (mean_fake - mean_int).abs() < 0.15,
        "fake-quant {mean_fake} vs integer {mean_int}: the tables' protocol must proxy deployment"
    );
}
