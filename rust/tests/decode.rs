//! Incremental-decode equivalence: KV-cached forwards must reproduce the
//! full-sequence (stateless) forward — bit-exact on the FP path, within
//! tolerance on the integer paths — across the edge shapes generation
//! meets in practice (1-token prompt, prompt == n_ctx − 1, single-head vs
//! multi-head), plus greedy-generation equivalence against a
//! full-recompute reference.

use crossquant::model::config::ModelConfig;
use crossquant::model::weights::synthetic_weights;
use crossquant::model::{block, IdentitySite, NativeModel, QuantPath, QuantizedModel};
use crossquant::quant::Bits;

fn cfg(n_heads: usize, seq_len: usize) -> ModelConfig {
    ModelConfig {
        vocab: 48,
        d_model: 16,
        n_layers: 2,
        n_heads,
        d_ff: 32,
        seq_len,
        eval_batch: 2,
    }
}

fn tokens(cfg: &ModelConfig, seed: u32) -> Vec<u32> {
    (0..cfg.seq_len).map(|i| ((i as u32 * 7 + seed * 13 + 1) % cfg.vocab as u32)).collect()
}

/// Feed `toks` through the KV cache with the given prefill split and
/// return one logits row per position (prefill rows + decode rows).
fn incremental_rows_native(
    model: &NativeModel,
    toks: &[u32],
    prefill: usize,
) -> Vec<Vec<f32>> {
    let mut state = model.new_decode_state();
    let mut rows = Vec::with_capacity(toks.len());
    let first = model.forward_incremental(&toks[..prefill], &mut state, &mut IdentitySite).unwrap();
    for i in 0..first.rows {
        rows.push(first.row(i).to_vec());
    }
    for &t in &toks[prefill..] {
        let step = model.forward_incremental(&[t], &mut state, &mut IdentitySite).unwrap();
        assert_eq!(step.rows, 1);
        rows.push(step.row(0).to_vec());
    }
    rows
}

fn incremental_rows_quantized(
    model: &QuantizedModel,
    toks: &[u32],
    prefill: usize,
) -> Vec<Vec<f32>> {
    let mut state = model.new_decode_state();
    let mut rows = Vec::with_capacity(toks.len());
    let first = model.forward_incremental(&toks[..prefill], &mut state).unwrap();
    for i in 0..first.rows {
        rows.push(first.row(i).to_vec());
    }
    for &t in &toks[prefill..] {
        let step = model.forward_incremental(&[t], &mut state).unwrap();
        rows.push(step.row(0).to_vec());
    }
    rows
}

#[test]
fn fp_incremental_decode_is_bit_exact_with_full_forward() {
    // edge shapes: single-head and multi-head; 1-token prompt and a
    // prompt filling all but the last context slot
    for (n_heads, seed) in [(1usize, 0u32), (2, 1), (4, 2)] {
        let c = cfg(n_heads, 12);
        let model = NativeModel::new(synthetic_weights(c, 40 + seed as u64));
        let toks = tokens(&c, seed);
        let full = model.forward_logits(&toks, &mut IdentitySite).unwrap();
        for prefill in [1usize, 2, c.seq_len / 2, c.seq_len - 1, c.seq_len] {
            let rows = incremental_rows_native(&model, &toks, prefill);
            assert_eq!(rows.len(), full.rows);
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(
                    row.as_slice(),
                    full.row(i),
                    "heads {n_heads}, prefill {prefill}, position {i}: FP decode must be bit-exact"
                );
            }
        }
    }
}

#[test]
fn integer_incremental_decode_matches_full_forward_per_token() {
    // per-token W8A8: activation codes are row-local, so cached decode
    // reproduces the full forward (tolerance guards against accumulation
    // order, not semantics)
    let c = cfg(2, 12);
    let w = synthetic_weights(c, 50);
    let model = QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::PerToken).unwrap();
    let toks = tokens(&c, 3);
    let full = model.forward_logits(&toks).unwrap();
    for prefill in [1usize, c.seq_len - 1] {
        let rows = incremental_rows_quantized(&model, &toks, prefill);
        for (i, row) in rows.iter().enumerate() {
            for (a, b) in row.iter().zip(full.row(i)) {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "prefill {prefill}, position {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn integer_incremental_decode_matches_full_forward_static_crossquant() {
    // calibrated static CrossQuant: the column factors are frozen at
    // calibration, so decode-time codes are row-local too
    let c = cfg(2, 12);
    let w = synthetic_weights(c, 51);
    let mut model =
        QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha: 0.15 })
            .unwrap();
    let calib: Vec<Vec<u32>> = (0..6).map(|s| tokens(&c, 20 + s)).collect();
    model.calibrate_static(0.15, &calib).unwrap();
    let toks = tokens(&c, 4);
    let full = model.forward_logits(&toks).unwrap();
    for prefill in [1usize, c.seq_len / 2, c.seq_len - 1] {
        let rows = incremental_rows_quantized(&model, &toks, prefill);
        for (i, row) in rows.iter().enumerate() {
            for (a, b) in row.iter().zip(full.row(i)) {
                assert!(
                    (a - b).abs() <= 1e-4 * b.abs().max(1.0),
                    "prefill {prefill}, position {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn fp_generate_greedy_matches_full_recompute_reference() {
    let c = cfg(2, 16);
    let model = NativeModel::new(synthetic_weights(c, 60));
    let prompt: Vec<u32> = tokens(&c, 5)[..4].to_vec();
    let max_new = 8;
    let cached = model.generate_greedy(&prompt, max_new, &mut IdentitySite).unwrap();
    // reference: no KV cache — rescore the whole growing sequence each
    // step, with the same sampler as the cached path so any divergence
    // must come from the logits
    let mut seq = prompt.clone();
    let mut reference = Vec::new();
    for _ in 0..max_new {
        let logits = model.forward_logits(&seq, &mut IdentitySite).unwrap();
        let next = block::argmax(logits.row(logits.rows - 1)) as u32;
        reference.push(next);
        seq.push(next);
    }
    assert_eq!(cached, reference, "KV-cached greedy must equal full-recompute greedy");
}

#[test]
fn quantized_generate_greedy_is_deterministic_for_every_path() {
    let c = cfg(2, 16);
    let w = synthetic_weights(c, 61);
    let prompt: Vec<u32> = tokens(&c, 6)[..5].to_vec();
    let per_token =
        QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::PerToken).unwrap();
    let dynamic =
        QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha: 0.15 })
            .unwrap();
    let mut stat =
        QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha: 0.15 })
            .unwrap();
    let calib: Vec<Vec<u32>> = (0..6).map(|s| tokens(&c, 30 + s)).collect();
    stat.calibrate_static(0.15, &calib).unwrap();
    for model in [&per_token, &dynamic, &stat] {
        let a = model.generate_greedy(&prompt, 8).unwrap();
        let b = model.generate_greedy(&prompt, 8).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|&t| (t as usize) < c.vocab));
    }
    // context accounting: prompt + max_new == n_ctx is legal, +1 is not
    assert!(per_token.generate_greedy(&prompt, c.seq_len - prompt.len()).is_ok());
    assert!(per_token.generate_greedy(&prompt, c.seq_len - prompt.len() + 1).is_err());
}
