//! Observability integration: histogram merge/quantile properties, span
//! ring behaviour under concurrent writers and readers, end-to-end span
//! coverage of a traced streaming generation, and the wire-level
//! trace/metrics protocol including Prometheus exposition.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crossquant::coordinator::scheduler::{CoordinatorConfig, EvalCoordinator, EvalRequest};
use crossquant::coordinator::{ActScheme, EvalServer};
use crossquant::model::weights::synthetic_weights;
use crossquant::model::ModelConfig;
use crossquant::obs::slo::{error_burn, latency_burn, SloInputs};
use crossquant::obs::{self, Histogram, Rolling, RollingCount, SloSpec, Span, SpanKind, SpanRing};
use crossquant::runtime::ArtifactStore;
use crossquant::tensor::SplitMix64;
use crossquant::util::Json;

fn small_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 48,
        eval_batch: 2,
    }
}

fn unique_dir(prefix: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!(
        "{prefix}-{}-{:?}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&p).unwrap();
    p
}

/// Coordinator over synthetic weights and an empty store: the native
/// executor serves every request, so these tests run on every build.
fn start_coordinator() -> (EvalCoordinator, std::path::PathBuf) {
    let cfg = small_cfg();
    let dir = unique_dir("cq-obs");
    let weights = synthetic_weights(cfg, 23);
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir: dir.clone() },
        cfg,
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 16,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    (coordinator, dir)
}

fn roundtrip(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line).expect("server must emit valid JSON")
}

// --- histogram properties ----------------------------------------------

#[test]
fn histogram_merge_of_shards_equals_histogram_of_union() {
    let mut rng = SplitMix64::new(7);
    let union = Histogram::new();
    let shards: Vec<Histogram> = (0..4).map(|_| Histogram::new()).collect();
    for i in 0..10_000u64 {
        // spread over ~10 decades, with a slice of overflow-range values
        let v = match rng.next_u64() % 10 {
            0 => rng.next_u64(),
            d => rng.next_u64() % 10u64.pow(d as u32),
        };
        shards[(i % 4) as usize].record(v);
        union.record(v);
    }
    let merged = Histogram::new();
    for s in &shards {
        merged.merge_from(s);
    }
    assert_eq!(merged.bucket_counts(), union.bucket_counts());
    assert_eq!(merged.count(), union.count());
    assert_eq!(merged.sum_us(), union.sum_us());
    assert_eq!(merged.overflow_count(), union.overflow_count());
    assert_eq!(merged.max_us(), union.max_us());
    for q in [0.0, 0.1, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
        assert_eq!(merged.quantile_us(q), union.quantile_us(q), "q = {q}");
    }
}

#[test]
fn histogram_quantiles_are_monotone_and_clamped() {
    let h = Histogram::new();
    let mut rng = SplitMix64::new(99);
    for _ in 0..5_000 {
        h.record(rng.next_u64() % 50_000_000);
    }
    let mut prev = 0u64;
    for i in 0..=100 {
        let q = i as f64 / 100.0;
        let v = h.quantile_us(q);
        assert!(v >= prev, "quantile must be monotone in q (q = {q}: {v} < {prev})");
        prev = v;
    }
    // the top quantile is tightened to the observed max, never a sentinel
    assert!(h.quantile_us(1.0) <= h.max_us());
}

// --- span ring ---------------------------------------------------------

#[test]
fn span_ring_survives_concurrent_writers_and_readers() {
    let ring = Arc::new(SpanRing::new(1024));
    let writers = 4u64;
    let per_writer = 2_000u64; // wraps the ring several times over
    let stop = Arc::new(AtomicBool::new(false));
    let reader = {
        let ring = ring.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut seen = 0usize;
            while !stop.load(Ordering::Relaxed) {
                for s in ring.snapshot() {
                    // writer invariant: aux == trace ^ dur. A torn read
                    // (fields from two different records) would break it.
                    assert_eq!(s.aux, s.trace ^ s.dur_us, "torn span read: {s:?}");
                    seen += 1;
                }
            }
            seen
        })
    };
    let handles: Vec<_> = (0..writers)
        .map(|w| {
            let ring = ring.clone();
            std::thread::spawn(move || {
                for i in 0..per_writer {
                    let trace = (w << 32) | i | 1;
                    let dur = i.wrapping_mul(0x9E37) & 0xFFFF;
                    ring.record(Span {
                        trace,
                        kind: SpanKind::DecodeToken,
                        start_us: i,
                        dur_us: dur,
                        aux: trace ^ dur,
                    });
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Relaxed);
    let seen = reader.join().unwrap();
    assert!(seen > 0, "concurrent reader must observe published spans");
    assert_eq!(ring.recorded(), writers * per_writer);
    // once writers are quiescent every slot is committed and readable
    assert_eq!(ring.snapshot().len(), ring.capacity());
}

// --- end-to-end span coverage ------------------------------------------

#[test]
fn traced_generate_spans_cover_request_wall_time() {
    let (coordinator, dir) = start_coordinator();
    let trace = obs::next_trace_id();
    let new_tokens = 32usize;
    let t0 = Instant::now();
    let prompt = vec![1, 2, 3, 4];
    let req = EvalRequest::generate(prompt, ActScheme::Fp, "w16", new_tokens).with_trace(trace);
    let (rx, handle) = coordinator.submit_streaming(req).expect("submit");
    let mut streamed = 0usize;
    while rx.recv_timeout(Duration::from_secs(60)).is_ok() {
        streamed += 1;
    }
    let resp = handle.wait().expect("generate");
    let wall_us = t0.elapsed().as_micros() as u64;
    assert_eq!(resp.generated.len(), new_tokens);
    assert_eq!(streamed, new_tokens);

    let spans = coordinator.metrics.spans.for_trace(trace);
    let count = |k: SpanKind| spans.iter().filter(|s| s.kind == k).count();
    assert_eq!(count(SpanKind::QueueWait), 1, "{spans:?}");
    assert_eq!(count(SpanKind::AdmissionWait), 1);
    assert_eq!(count(SpanKind::Prefill), 1);
    // prefill emits the first token; every later token gets a decode span
    assert_eq!(count(SpanKind::DecodeToken), new_tokens - 1);

    // the four stage kinds tile submit → last token; only channel
    // delivery tails are uncovered, so ≥95% of wall time is accounted for
    let stages = [
        SpanKind::QueueWait,
        SpanKind::AdmissionWait,
        SpanKind::Prefill,
        SpanKind::DecodeToken,
    ];
    let stage_spans = spans.iter().filter(|s| stages.contains(&s.kind));
    let covered: u64 = stage_spans.map(|s| s.dur_us).sum();
    assert!(
        covered as f64 >= 0.95 * wall_us as f64,
        "stage spans cover {covered}us of {wall_us}us wall time"
    );

    // an untraced request must not add spans
    let before = coordinator.metrics.spans.recorded();
    let quiet = EvalRequest::generate(vec![1, 2, 3], ActScheme::Fp, "w16", 4);
    coordinator.submit(quiet).expect("submit").wait().expect("generate");
    assert_eq!(coordinator.metrics.spans.recorded(), before);

    coordinator.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// --- wire protocol -----------------------------------------------------

/// Every sample line of a Prometheus text body must parse as
/// `name{labels} value` with a finite value.
fn assert_prometheus_body(body: &str) {
    let mut samples = 0usize;
    for line in body.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (_, value) = line.rsplit_once(' ').expect("sample line has a value");
        let v: f64 = value.parse().expect("sample value parses as f64");
        assert!(v.is_finite() || v.is_nan(), "non-finite sample: {line}");
        samples += 1;
    }
    assert!(samples > 0, "exposition body has no samples");
}

#[test]
fn trace_query_and_prometheus_exposition_over_the_wire() {
    let cfg = small_cfg();
    let dir = unique_dir("cq-obs-wire");
    let weights = synthetic_weights(cfg, 23);
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir: dir.clone() },
        cfg,
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 16,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    // sample every dynamic-scheme forward so one request populates gauges
    coordinator.metrics.kernel.configure(true, 0.19, 1);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = EvalServer::new(coordinator).serve(listener);
    });

    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // a traced dynamic-CrossQuant generation; the response echoes the id
    let req = r#"{"tokens": [1, 2, 3, 4], "scheme": "crossquant", "alpha": 0.15, "max_new_tokens": 6, "weight_set": "w16", "trace": "obs-wire-test"}"#;
    let resp = roundtrip(&mut stream, &mut reader, req);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    let echoed = resp.get("trace").and_then(|t| t.as_str());
    let id = echoed.expect("trace echoed").to_string();

    // spans are queryable by that id: dispatchless worker-side taxonomy
    let tr = roundtrip(&mut stream, &mut reader, &format!(r#"{{"cmd": "trace", "id": "{id}"}}"#));
    assert_eq!(tr.get("ok"), Some(&Json::Bool(true)), "{tr:?}");
    assert_eq!(tr.get("trace").and_then(|t| t.as_str()), Some(id.as_str()));
    let spans = tr.get("spans").unwrap().as_arr().unwrap();
    let mut kinds: Vec<&str> = Vec::new();
    for s in spans {
        kinds.push(s.get("kind").and_then(|k| k.as_str()).expect("span kind"));
    }
    for want in ["queue_wait", "admission_wait", "prefill", "decode_token"] {
        assert!(kinds.contains(&want), "missing {want} span in {kinds:?}");
    }
    assert_eq!(kinds.iter().filter(|&k| k == "decode_token").count(), 5);
    for s in spans {
        assert_eq!(s.get("trace").and_then(|t| t.as_str()), Some(id.as_str()));
        assert!(s.get("dur_us").and_then(|d| d.as_f64()).is_some(), "{s:?}");
    }

    // the same trace as Chrome trace_event JSON
    let chrome = roundtrip(
        &mut stream,
        &mut reader,
        &format!(r#"{{"cmd": "trace", "id": "{id}", "format": "chrome"}}"#),
    );
    assert_eq!(chrome.get("ok"), Some(&Json::Bool(true)));
    let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), spans.len());
    for e in events {
        assert_eq!(e.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert!(e.get("name").and_then(|n| n.as_str()).is_some());
        assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(e.get("dur").and_then(|d| d.as_f64()).is_some());
    }

    // plain metrics now carry windowed latency and per-site kernel gauges
    let m = roundtrip(&mut stream, &mut reader, r#"{"cmd": "metrics"}"#);
    let latency = m.get("latency").expect("latency section");
    for track in ["ttft", "inter_token", "queue_wait", "batch_forward"] {
        let t = latency.get(track).unwrap_or_else(|| panic!("missing track {track}"));
        assert!(t.get("total").and_then(|j| j.get("p99_us")).is_some(), "{track}");
        assert!(t.get("w60s").is_some(), "{track} missing rolling window");
    }
    let ttft_total = latency.get("ttft").unwrap().get("total").unwrap();
    assert!(ttft_total.get("count").unwrap().as_f64() >= Some(1.0));
    let kernel = m.get("kernel").expect("kernel section");
    assert_eq!(kernel.get("enabled"), Some(&Json::Bool(true)));
    let sites = kernel.get("sites").unwrap().as_arr().unwrap();
    assert!(!sites.is_empty(), "dynamic forwards must populate kernel gauges");
    for site in sites {
        let frac = site.get("kernel_fraction").and_then(|f| f.as_f64()).unwrap();
        assert!((0.0..=1.0).contains(&frac), "kernel fraction {frac}");
        assert!(site.get("row_absmax_mean").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(site.get("col_absmax_mean").and_then(|v| v.as_f64()).unwrap() > 0.0);
    }

    // Prometheus exposition: parseable body with the cq_* families
    let prom_req = r#"{"cmd": "metrics", "format": "prometheus"}"#;
    let prom = roundtrip(&mut stream, &mut reader, prom_req);
    assert_eq!(prom.get("ok"), Some(&Json::Bool(true)));
    let body = prom.get("body").and_then(|b| b.as_str()).expect("exposition body");
    assert_prometheus_body(body);
    for family in ["cq_requests_submitted_total", "cq_latency_us", "cq_kernel_fraction"] {
        assert!(body.contains(family), "missing {family} in exposition");
    }

    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// SLO burn-rate properties (obs::slo under an injected clock)
// ---------------------------------------------------------------------------

/// Budget consumption is monotone in the violation count: with the total
/// sample count held fixed, adding violations never lowers any burn rate.
#[test]
fn burn_rate_is_monotone_in_violation_count() {
    const N: u64 = 40;
    const EPOCH: u64 = 777;
    let mut prev_latency = -1.0f64;
    let mut prev_error = -1.0f64;
    for v in 0..=N {
        let rolling = Rolling::new();
        for i in 0..N {
            // violations land far above the 1 ms target so the log-bucket
            // boundary cannot blur the count; compliant samples far below
            rolling.record_at(EPOCH, if i < v { 50_000 } else { 100 });
        }
        let latency = latency_burn(&rolling.window_at(EPOCH, 10), 1_000);
        let error = error_burn(N - v, v, 0.01);
        assert!(
            latency >= prev_latency,
            "latency burn fell from {prev_latency} to {latency} at {v} violations"
        );
        assert!(error >= prev_error, "error burn fell from {prev_error} to {error} at {v} errors");
        prev_latency = latency;
        prev_error = error;
    }
    // the endpoints pin the scale: 0 violations burns 0, all-violations
    // burns 1/budget
    assert_eq!(prev_error, 100.0);
    assert!((prev_latency - 100.0).abs() < 1e-9);
}

/// Rolling-window rotation under an injected clock never double-counts:
/// one observation per epoch second always yields exactly
/// `min(elapsed, window)` samples in the window, reads are idempotent,
/// and a clock jump far past the ring finds nothing stale.
#[test]
fn window_rotation_under_injected_clock_never_double_counts() {
    let rolling = Rolling::new();
    let counts = RollingCount::new();
    let base = 5_000u64;
    for i in 0..200u64 {
        let now = base + i;
        rolling.record_at(now, 10_000);
        counts.record_at(now);
        let expect = (i + 1).min(60);
        assert_eq!(rolling.window_at(now, 60).count(), expect, "at second {i}");
        assert_eq!(counts.window_at(now, 60), expect, "at second {i}");
        assert_eq!(rolling.window_at(now, 1).count(), 1, "1s window at second {i}");
        // a second read of the same window is a pure merge — no mutation
        assert_eq!(rolling.window_at(now, 60).count(), expect);
    }
    // jumping the clock far beyond the 64-slot ring leaves every slot
    // stale: the window must come back empty, not recycled
    assert_eq!(rolling.window_at(base + 10_000, 60).count(), 0);
    assert_eq!(counts.window_at(base + 10_000, 60), 0);
}

/// The multi-window alert rule fires in the right order on a synthetic
/// violation stream: after a long healthy period, the fast windows alert
/// on the first bad second, the slow 60 s window only once the overload
/// has consumed enough of its budget — and shedding starts exactly when
/// both agree.
#[test]
fn synthetic_violation_stream_alerts_fast_before_slow() {
    let ttft = Rolling::new();
    let inter = Rolling::new();
    let ok = RollingCount::new();
    let err = RollingCount::new();
    let inputs = SloInputs { ttft: &ttft, inter_token: &inter, ok: &ok, err: &err };
    let spec = SloSpec {
        ttft_p99_us: 1_000,
        inter_token_p99_us: u64::MAX / 2,
        error_rate: 0.01,
        burn_threshold: 10.0,
    };
    // 60 s of healthy traffic: 10 compliant TTFTs per second
    let t0 = 1_000u64;
    for s in 0..60 {
        for _ in 0..10 {
            ttft.record_at(t0 + s, 100);
            ok.record_at(t0 + s);
        }
    }
    let calm = spec.evaluate_at(&inputs, t0 + 59);
    assert!(!calm.fast_alert && !calm.slow_alert && !calm.shedding);

    // then every request violates; at 10/s the 60 s window crosses the
    // burn-10 line (10% violating) after 6 bad seconds
    let mut first_shed = None;
    for k in 1..=20u64 {
        let now = t0 + 59 + k;
        for _ in 0..10 {
            ttft.record_at(now, 50_000);
            ok.record_at(now);
        }
        let report = spec.evaluate_at(&inputs, now);
        assert!(report.fast_alert, "fast windows must alert from bad second 1 (k={k})");
        assert_eq!(report.shedding, report.fast_alert && report.slow_alert);
        if report.shedding && first_shed.is_none() {
            first_shed = Some(k);
        }
    }
    let first_shed = first_shed.expect("sustained overload must eventually shed");
    assert!(
        (2..=7).contains(&first_shed),
        "slow window confirmed after {first_shed} bad seconds — the one-second blip guard"
    );
}
