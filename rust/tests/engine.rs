//! Continuous-batching engine integration tests, through the public
//! coordinator API: concurrent streamed generations must be bit-identical
//! to sequential `generate_greedy` for every served scheme, a small KV
//! pool must queue (not corrupt, not deadlock) excess sequences, a
//! request admitted mid-decode must join the running batch correctly, and
//! graceful shutdown must drain in-flight work and join the threads.
//!
//! Everything runs over synthetic weights and the native executor — no
//! artifacts required, so these run on every build.

use std::time::Duration;

use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{ActScheme, EngineConfig, EvalCoordinator, EvalRequest};
use crossquant::corpus::CorpusGen;
use crossquant::model::weights::synthetic_weights;
use crossquant::model::{
    IdentitySite, ModelConfig, NativeModel, QuantPath, QuantSite, QuantizedModel,
};
use crossquant::quant::crossquant::CrossQuant;
use crossquant::quant::Bits;
use crossquant::runtime::ArtifactStore;

const SEED: u64 = 41;

fn cfg() -> ModelConfig {
    ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        seq_len: 48,
        eval_batch: 2,
    }
}

/// std has no tempdir; 8 lines suffice.
struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new() -> TempDir {
        let p = std::env::temp_dir().join(format!(
            "cq-engine-{}-{:?}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn start(engine: EngineConfig) -> (EvalCoordinator, TempDir) {
    let dir = TempDir::new();
    let weights = synthetic_weights(cfg(), SEED);
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir: dir.0.clone() },
        cfg(),
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 64,
            engine,
            artifacts: Vec::new(),
        },
    );
    (coordinator, dir)
}

/// Sequential single-request reference for one scheme — what
/// `generate_greedy` alone on the executor (the PR 3 serial path) would
/// produce for this prompt.
fn reference(scheme: ActScheme, prompt: &[u32], max_new: usize) -> Vec<u32> {
    let weights = synthetic_weights(cfg(), SEED);
    match scheme {
        ActScheme::Fp => NativeModel::new(weights)
            .generate_greedy(prompt, max_new, &mut IdentitySite)
            .unwrap(),
        ActScheme::CrossQuant { alpha, qmax } => {
            assert_eq!(qmax, 127.0);
            let mut site = QuantSite::new(CrossQuant::new(alpha, Bits::Int8));
            NativeModel::new(weights).generate_greedy(prompt, max_new, &mut site).unwrap()
        }
        ActScheme::CrossQuantStatic { alpha, .. } => {
            let mut qm = QuantizedModel::new(
                &weights,
                Bits::Int8,
                Bits::Int8,
                QuantPath::CrossQuant { alpha },
            )
            .unwrap();
            // the executor's exact calibration stream (scheduler.rs)
            let mut gen = CorpusGen::new(cfg().vocab, 0x5CA1E);
            let calib: Vec<Vec<u32>> = (0..8).map(|_| gen.sequence(cfg().seq_len)).collect();
            qm.calibrate_static(alpha, &calib).unwrap();
            qm.generate_greedy(prompt, max_new).unwrap()
        }
        other => panic!("no reference for {other:?}"),
    }
}

#[test]
fn concurrent_streams_bit_identical_to_sequential_for_every_scheme() {
    let (coordinator, _guard) = start(EngineConfig::default());
    let schemes = [
        ActScheme::Fp,
        ActScheme::CrossQuant { alpha: 1.0, qmax: 127.0 }, // per-token
        ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 },
        ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 },
    ];
    for scheme in schemes {
        let n = 4;
        let prompts: Vec<Vec<u32>> =
            (0..n).map(|i| CorpusGen::new(cfg().vocab, 7 + i as u64).sequence(5)).collect();
        let max_new = 8;
        // all sessions in flight at once, each streaming its tokens
        let sessions: Vec<_> = prompts
            .iter()
            .map(|p| {
                coordinator
                    .submit_streaming(EvalRequest::generate(p.clone(), scheme, "w16", max_new))
                    .unwrap()
            })
            .collect();
        for (p, (events, handle)) in prompts.iter().zip(sessions) {
            let resp = handle.wait().unwrap_or_else(|e| panic!("{scheme:?}: {e}"));
            let streamed: Vec<u32> = events.iter().map(|e| e.token).collect();
            assert_eq!(streamed, resp.generated, "{scheme:?}: stream == final payload");
            let expect = reference(scheme, p, max_new);
            assert_eq!(resp.generated, expect, "{scheme:?}: engine == sequential decode");
        }
    }
}

#[test]
fn tiny_kv_pool_queues_and_all_sequences_complete_exactly() {
    // 2 KV slots for 6 concurrent sessions: four must wait for a lease;
    // every one still decodes its exact sequential tokens
    let slot = 2 * cfg().n_layers * cfg().seq_len * cfg().d_model * 4;
    let (coordinator, _guard) = start(EngineConfig {
        max_active_seqs: 16,
        kv_pool_bytes: Some(2 * slot),
        max_waiting: 16,
        ..EngineConfig::default()
    });
    let scheme = ActScheme::Fp;
    let prompts: Vec<Vec<u32>> =
        (0..6).map(|i| CorpusGen::new(cfg().vocab, 20 + i as u64).sequence(4)).collect();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            coordinator.submit(EvalRequest::generate(p.clone(), scheme, "w16", 10)).unwrap()
        })
        .collect();
    for (p, h) in prompts.iter().zip(handles) {
        let resp = h.wait().unwrap();
        assert_eq!(resp.generated, reference(scheme, p, 10));
    }
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(coordinator.metrics.kv_pool_slots.load(Relaxed), 2, "budget caps the pool");
    assert_eq!(coordinator.metrics.kv_pool_in_use.load(Relaxed), 0, "all slots released");
    assert_eq!(coordinator.metrics.completed.load(Relaxed), 6);
}

#[test]
fn mid_flight_join_produces_correct_tokens_for_both_sequences() {
    let (coordinator, _guard) = start(EngineConfig::default());
    let scheme = ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 };
    let a_prompt = vec![1u32, 2, 3];
    let b_prompt = vec![9u32, 9];
    // A streams 24 tokens; B is submitted only after A has demonstrably
    // started decoding, so B joins a running batch mid-flight
    let (a_events, a_handle) = coordinator
        .submit_streaming(EvalRequest::generate(a_prompt.clone(), scheme, "w16", 24))
        .unwrap();
    let first = a_events.recv_timeout(Duration::from_secs(120)).expect("A must start");
    let b_handle = coordinator
        .submit(EvalRequest::generate(b_prompt.clone(), scheme, "w16", 6))
        .unwrap();
    let b = b_handle.wait_timeout(Duration::from_secs(120)).unwrap();
    let a = a_handle.wait_timeout(Duration::from_secs(120)).unwrap();
    let a_expect = reference(scheme, &a_prompt, 24);
    assert_eq!(first.token, a_expect[0], "stream starts with the first decoded token");
    assert_eq!(a.generated, a_expect, "A unaffected by B joining mid-decode");
    assert_eq!(b.generated, reference(scheme, &b_prompt, 6), "B correct from a late join");
}

#[test]
fn admission_pressure_never_hangs_or_corrupts() {
    // one KV slot, queue of one, many long generations in flight at once:
    // every response must be either its exact sequential tokens or the
    // structured capacity error — never a hang, never wrong tokens.
    // (Deterministic rejection ordering is pinned by the engine's unit
    // tests; this exercises the wiring end-to-end under pressure.)
    let slot = 2 * cfg().n_layers * cfg().seq_len * cfg().d_model * 4;
    let (coordinator, _guard) = start(EngineConfig {
        max_active_seqs: 1,
        kv_pool_bytes: Some(slot),
        max_waiting: 1,
        ..EngineConfig::default()
    });
    let scheme = ActScheme::Fp;
    let prompts: Vec<Vec<u32>> =
        (0..5).map(|i| CorpusGen::new(cfg().vocab, 60 + i as u64).sequence(3)).collect();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| {
            coordinator.submit(EvalRequest::generate(p.clone(), scheme, "w16", 20)).unwrap()
        })
        .collect();
    let mut completed = 0usize;
    for (p, h) in prompts.iter().zip(handles) {
        match h.wait_timeout(Duration::from_secs(120)) {
            Ok(resp) => {
                assert_eq!(resp.generated, reference(scheme, p, 20));
                completed += 1;
            }
            Err(e) => assert!(
                format!("{e}").contains("admission queue full"),
                "unexpected error: {e}"
            ),
        }
    }
    assert!(completed >= 1, "at least the first admitted sequence must complete");
}

#[test]
fn shutdown_drains_in_flight_generation_and_joins_threads() {
    let (coordinator, _guard) = start(EngineConfig::default());
    let scheme = ActScheme::Fp;
    let handle = coordinator
        .submit(EvalRequest::generate(vec![3, 1, 4], scheme, "w16", 12))
        .unwrap();
    // shutdown returns only after the batcher flushed, the engine drained
    // every in-flight sequence, and both threads joined
    coordinator.shutdown();
    let resp = handle.wait().expect("in-flight request must be drained, not dropped");
    assert_eq!(resp.generated, reference(scheme, &[3, 1, 4], 12));
    // the coordinator is now closed: new work is refused cleanly
    let err = coordinator
        .submit(EvalRequest::generate(vec![1], scheme, "w16", 2))
        .expect_err("submit after shutdown must fail");
    assert!(format!("{err}").contains("shut down"), "unexpected error: {err}");
    // idempotent
    coordinator.shutdown();
}

#[test]
fn scoring_and_generation_interleave_without_interference() {
    let (coordinator, _guard) = start(EngineConfig::default());
    let gen_scheme = ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 };
    let score_scheme = ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 };
    let gen_handle = coordinator
        .submit(EvalRequest::generate(vec![2, 4, 6], gen_scheme, "w16", 16))
        .unwrap();
    // scoring requests land while the engine is mid-decode
    let mut corp = CorpusGen::new(cfg().vocab, 5);
    let score_handles: Vec<_> = (0..4)
        .map(|_| {
            coordinator
                .submit(EvalRequest::score(corp.sequence(cfg().seq_len), score_scheme, "w16"))
                .unwrap()
        })
        .collect();
    for h in score_handles {
        let r = h.wait_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(r.nll.len(), cfg().seq_len - 1);
        assert!(r.nll.iter().all(|v| v.is_finite()));
    }
    let g = gen_handle.wait_timeout(Duration::from_secs(120)).unwrap();
    assert_eq!(g.generated, reference(gen_scheme, &[2, 4, 6], 16));
}
