//! `.cqa` deployment-artifact integration tests: quantize → write →
//! mmap-load → serve must be **bit-identical** to the in-memory
//! `calibrate_static` model (logits, NLLs, greedy decodes), across head
//! counts and the INT4 nibble-packed payload path; corruption of any
//! byte must surface as a structured error, never a panic; and the
//! coordinator must serve a mounted artifact without any FP weight set.

use std::path::PathBuf;
use std::time::Duration;

use crossquant::coordinator::scheduler::{CoordinatorConfig, EvalCoordinator, EvalRequest};
use crossquant::coordinator::ActScheme;
use crossquant::model::weights::synthetic_weights;
use crossquant::model::{ModelConfig, QuantPath, QuantizedModel};
use crossquant::quant::artifact::Artifact;
use crossquant::quant::gemm::PackedInt8;
use crossquant::quant::Bits;
use crossquant::runtime::ArtifactStore;
use crossquant::tensor::SplitMix64;

fn cfg(n_heads: usize) -> ModelConfig {
    ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 2,
        n_heads,
        d_ff: 32,
        seq_len: 20,
        eval_batch: 2,
    }
}

fn calib(cfg: &ModelConfig) -> Vec<Vec<u32>> {
    (0..6)
        .map(|s| (0..cfg.seq_len).map(|i| ((i * 7 + s * 11) % cfg.vocab) as u32).collect())
        .collect()
}

fn toks(cfg: &ModelConfig) -> Vec<u32> {
    (0..cfg.seq_len).map(|i| ((i * 5 + 3) % cfg.vocab) as u32).collect()
}

/// Build + calibrate the in-memory static model the artifact round-trips
/// against.
fn build_calibrated(cfg: ModelConfig, bits: Bits, seed: u64, alpha: f32) -> QuantizedModel {
    let w = synthetic_weights(cfg, seed);
    let mut qm =
        QuantizedModel::new(&w, bits, Bits::Int8, QuantPath::CrossQuant { alpha }).unwrap();
    qm.calibrate_static(alpha, &calib(&cfg)).unwrap();
    qm
}

struct TempFile(PathBuf);

impl TempFile {
    fn new(name: &str) -> TempFile {
        TempFile(std::env::temp_dir().join(format!("cqa-it-{}-{name}", std::process::id())))
    }
}

impl Drop for TempFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

struct TempDir(PathBuf);

impl TempDir {
    fn new(name: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!("cqa-itd-{}-{name}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[test]
fn roundtrip_bit_identical_logits_across_head_counts() {
    for (i, n_heads) in [1usize, 2, 4].into_iter().enumerate() {
        let c = cfg(n_heads);
        let qm = build_calibrated(c, Bits::Int8, 100 + i as u64, 0.15);
        let f = TempFile::new(&format!("heads{n_heads}.cqa"));
        qm.write_artifact(&f.0).unwrap();
        let loaded = QuantizedModel::load_artifact(&f.0).unwrap();
        assert!(matches!(loaded.path, QuantPath::CrossQuantStatic { .. }));
        let t = toks(&c);
        let a = qm.forward_logits(&t).unwrap();
        let b = loaded.forward_logits(&t).unwrap();
        assert_eq!(a.data, b.data, "n_heads={n_heads}: logits must be bit-identical");
        assert_eq!(
            qm.forward_nll(&t).unwrap(),
            loaded.forward_nll(&t).unwrap(),
            "n_heads={n_heads}: NLLs must be bit-identical"
        );
        // a re-saved artifact is byte-identical to the original (the
        // loader retains everything the writer ships)
        let f2 = TempFile::new(&format!("heads{n_heads}-resave.cqa"));
        loaded.write_artifact(&f2.0).unwrap();
        assert_eq!(std::fs::read(&f.0).unwrap(), std::fs::read(&f2.0).unwrap());
    }
}

#[test]
fn roundtrip_int4_nibble_packed_payload() {
    let c = cfg(2);
    let qm = build_calibrated(c, Bits::Int4, 7, 0.15);
    let f = TempFile::new("int4.cqa");
    qm.write_artifact(&f.0).unwrap();
    let art = Artifact::open(&f.0).unwrap();
    assert_eq!(art.weight_bits, Bits::Int4);
    // the shipped panel payload is nibble-packed: half the buffer bytes
    let s = art.section("layer0.wq.panels").unwrap();
    assert_eq!(s.len, PackedInt8::layout_bytes(16, 16).div_ceil(2));
    let loaded = QuantizedModel::from_artifact(&art).unwrap();
    let t = toks(&c);
    assert_eq!(
        qm.forward_logits(&t).unwrap().data,
        loaded.forward_logits(&t).unwrap().data,
        "int4 logits must be bit-identical"
    );
}

#[test]
fn int8_panels_serve_zero_copy_from_the_mapping() {
    let c = cfg(2);
    let qm = build_calibrated(c, Bits::Int8, 8, 0.15);
    let f = TempFile::new("zerocopy.cqa");
    qm.write_artifact(&f.0).unwrap();
    let art = Artifact::open(&f.0).unwrap();
    if !art.is_mapped() {
        return; // platform without mmap: nothing to pin
    }
    for name in ["layer0.wq.panels", "layer1.w2.panels", "w_out.panels"] {
        let p = art.panels(name).unwrap();
        assert!(p.is_mapped(), "{name} must be borrowed from the file mapping");
    }
}

#[test]
fn greedy_generation_matches_in_memory_model() {
    let c = cfg(2);
    let qm = build_calibrated(c, Bits::Int8, 9, 0.15);
    let f = TempFile::new("gen.cqa");
    qm.write_artifact(&f.0).unwrap();
    let loaded = QuantizedModel::load_artifact(&f.0).unwrap();
    let want = qm.generate_greedy(&[1, 2, 3], 8).unwrap();
    assert_eq!(loaded.generate_greedy(&[1, 2, 3], 8).unwrap(), want);
}

#[test]
fn corruption_never_panics_and_truncation_is_structured() {
    let c = cfg(2);
    let qm = build_calibrated(c, Bits::Int8, 10, 0.15);
    let f = TempFile::new("fuzz.cqa");
    qm.write_artifact(&f.0).unwrap();
    let good = std::fs::read(&f.0).unwrap();

    // every strict truncation yields a structured error, never a panic
    for cut in [0usize, 1, 37, 63, 64, 200, good.len() / 2, good.len() - 1] {
        let err = Artifact::from_bytes(good[..cut].to_vec()).unwrap_err();
        assert!(
            format!("{err:#}").contains("truncated"),
            "cut at {cut}: expected a truncation error, got: {err:#}"
        );
    }

    // fuzz-style bit flips over random positions: never a panic; either a
    // structured load error or — when only alignment padding was hit — a
    // still-valid artifact that still rebuilds into a model
    let mut rng = SplitMix64::new(0xC0FFEE);
    for _ in 0..256 {
        let pos = rng.below(good.len());
        let bit = 1u8 << rng.below(8);
        let mut bytes = good.clone();
        bytes[pos] ^= bit;
        match Artifact::from_bytes(bytes) {
            Ok(art) => {
                let _ = QuantizedModel::from_artifact(&art);
            }
            Err(e) => {
                assert!(!format!("{e:#}").is_empty());
            }
        }
    }
}

#[test]
fn broken_mount_surfaces_structured_error() {
    let c = cfg(2);
    let dir = TempDir::new("broken-mount");
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir: dir.0.clone() },
        c,
        vec![],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 16,
            engine: Default::default(),
            artifacts: vec![("w16".to_string(), dir.0.join("missing.cqa"))],
        },
    );
    let scheme = ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 };
    let err = coordinator
        .submit(EvalRequest::score(toks(&c), scheme, "w16"))
        .unwrap()
        .wait()
        .unwrap_err();
    // the mount failure reason reaches the requester, not a generic
    // "unknown weight set"
    assert!(format!("{err:#}").contains("failed to load"), "{err:#}");
    coordinator.shutdown();
}

#[test]
fn coordinator_serves_mounted_artifact_without_fp_weights() {
    let c = cfg(2);
    let alpha = 0.15f32;
    // the in-memory reference, calibrated on the exact stream the
    // artifact was built from
    let reference = build_calibrated(c, Bits::Int8, 11, alpha);
    let f = TempFile::new("served.cqa");
    reference.write_artifact(&f.0).unwrap();

    let dir = TempDir::new("serve");
    // note: zero FP weight sets — weights.bin is never read
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir: dir.0.clone() },
        c,
        vec![],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 64,
            engine: Default::default(),
            artifacts: vec![("w16".to_string(), f.0.clone())],
        },
    );
    let t = toks(&c);
    let scheme = ActScheme::CrossQuantStatic { alpha, qmax: 127.0 };

    // scoring: bit-identical to the in-memory calibrated model
    let resp = coordinator
        .submit(EvalRequest::score(t.clone(), scheme, "w16"))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(resp.nll, reference.forward_nll(&t).unwrap());

    // generation through the continuous-batching engine: same tokens
    let gen = coordinator
        .submit(EvalRequest::generate(vec![1, 2, 3], scheme, "w16", 5))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(gen.generated, reference.generate_greedy(&[1, 2, 3], 5).unwrap());

    // a non-static scheme on the artifact-only set fails structurally
    let err = coordinator
        .submit(EvalRequest::score(t.clone(), ActScheme::Fp, "w16"))
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(format!("{err:#}").contains("artifact-only"), "{err:#}");

    // an α the artifact was not calibrated for cannot be served without
    // FP weights — structured error, not a panic
    let other = ActScheme::CrossQuantStatic { alpha: 0.5, qmax: 127.0 };
    let err = coordinator
        .submit(EvalRequest::score(t, other, "w16"))
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(format!("{err:#}").contains("artifact-only"), "{err:#}");

    coordinator.shutdown();
}
