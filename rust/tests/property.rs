//! Property-based tests over the quantization invariants (hand-rolled
//! randomized driver — the offline build has no proptest; see Cargo.toml).
//! Each property runs across hundreds of random shapes / α / bit-widths
//! and shrinks nothing but reports the failing seed, which reproduces
//! deterministically.

use crossquant::analysis::{kernel_fraction, kernel_mask};
use crossquant::quant::{
    crossquant::CrossQuant, pack::PackedMatrix, per_channel::GroupWise, per_token::PerToken,
    remove_kernel::RemoveKernel, ActQuantizer, Bits,
};
use crossquant::tensor::{Matrix, SplitMix64};

const CASES: usize = 200;

/// Random matrix with occasional outlier columns and exact zeros.
fn arb_matrix(rng: &mut SplitMix64) -> Matrix {
    let rows = 1 + rng.below(60);
    let cols = 1 + rng.below(60);
    let mut x = Matrix::randn(rows, cols, 1.0, rng);
    if rng.uniform() < 0.5 {
        let n_out = 1 + rng.below(3.min(cols));
        for k in 0..n_out {
            let j = rng.below(cols);
            let scale = 10.0 + rng.uniform() as f32 * 90.0;
            for i in 0..rows {
                let v = x.get(i, j) * scale;
                x.set(i, j, v);
            }
            let _ = k;
        }
    }
    if rng.uniform() < 0.3 {
        // sprinkle exact zeros (kernel definition excludes them)
        for _ in 0..rows * cols / 10 {
            let idx = rng.below(rows * cols);
            x.data[idx] = 0.0;
        }
    }
    x
}

fn arb_alpha(rng: &mut SplitMix64) -> f32 {
    (rng.uniform() as f32 * 100.0).round() / 100.0
}

fn arb_bits(rng: &mut SplitMix64) -> Bits {
    match rng.below(3) {
        0 => Bits::Int4,
        1 => Bits::Int8,
        _ => Bits::Other(6),
    }
}

/// Definition 1 / eq. 4: the zero-bound mask predicts exactly which
/// non-zero elements the quantizer maps to zero.
#[test]
fn prop_kernel_mask_equals_actual_zeros() {
    let mut rng = SplitMix64::new(1);
    for case in 0..CASES {
        let x = arb_matrix(&mut rng);
        let alpha = arb_alpha(&mut rng);
        let bits = arb_bits(&mut rng);
        let q = CrossQuant::new(alpha, bits);
        let field = q.delta_field(&x);
        let mask = kernel_mask(&x, &field);
        let out = q.fake_quant(&x);
        for idx in 0..x.len() {
            let zeroed = out.data[idx] == 0.0 && x.data[idx] != 0.0;
            assert_eq!(mask[idx], zeroed, "case {case} idx {idx} x={}", x.data[idx]);
        }
    }
}

/// Paper §4.2 Case I: wherever c_j < t_i, the CrossQuant zero bound is
/// strictly below the per-token bound (for α < 1).
#[test]
fn prop_case_one_bound_shrinks() {
    let mut rng = SplitMix64::new(2);
    for _ in 0..CASES {
        let x = arb_matrix(&mut rng);
        let alpha = (arb_alpha(&mut rng)).min(0.99);
        let cq = CrossQuant::new(alpha, Bits::Int8).delta_field(&x);
        let pt = PerToken::new(Bits::Int8).delta_field(&x);
        let t = x.row_abs_max();
        let c = x.col_abs_max();
        for i in 0..x.rows {
            for j in 0..x.cols {
                if c[j] < t[i] && t[i] > 1e-6 && c[j] > 1e-6 {
                    assert!(
                        cq.zero_bound(i, j) < pt.zero_bound(i, j) * 1.0001,
                        "α={alpha} t={} c={}",
                        t[i],
                        c[j]
                    );
                }
            }
        }
    }
}

/// Fake-quant reconstruction error is bounded by half the scale step for
/// elements inside the clip range.
#[test]
fn prop_dequant_error_bounded() {
    let mut rng = SplitMix64::new(3);
    for _ in 0..CASES {
        let x = arb_matrix(&mut rng);
        let alpha = arb_alpha(&mut rng);
        let bits = arb_bits(&mut rng);
        let q = CrossQuant::new(alpha, bits);
        let field = q.delta_field(&x);
        let out = q.fake_quant(&x);
        for i in 0..x.rows {
            for j in 0..x.cols {
                let d = field.delta(i, j);
                let v = x.get(i, j);
                if v.abs() <= q.qmax() * d {
                    let err = (v - out.get(i, j)).abs();
                    assert!(err <= 0.5 * d * 1.001 + 1e-9, "v={v} err={err} Δ={d}");
                }
            }
        }
    }
}

/// α = 1 CrossQuant coincides with per-token (same scale field).
#[test]
fn prop_alpha_one_is_per_token() {
    let mut rng = SplitMix64::new(4);
    for _ in 0..CASES {
        let x = arb_matrix(&mut rng);
        let bits = arb_bits(&mut rng);
        let a = CrossQuant::new(1.0, bits).fake_quant(&x);
        let b = PerToken::new(bits).fake_quant(&x);
        for (u, v) in a.data.iter().zip(&b.data) {
            assert!((u - v).abs() <= 1e-5 * u.abs().max(1.0), "{u} vs {v}");
        }
    }
}

/// Kernel fractions are monotone in bit-width: coarser grids (Int4) have
/// at-least-as-large kernels as Int8 under the same scheme.
#[test]
fn prop_kernel_monotone_in_bits() {
    let mut rng = SplitMix64::new(5);
    for _ in 0..CASES {
        let x = arb_matrix(&mut rng);
        let alpha = arb_alpha(&mut rng);
        let k8 = kernel_fraction(&x, &CrossQuant::new(alpha, Bits::Int8).delta_field(&x));
        let k4 = kernel_fraction(&x, &CrossQuant::new(alpha, Bits::Int4).delta_field(&x));
        assert!(k4 >= k8 - 1e-7, "k4={k4} k8={k8}");
    }
}

/// Packing round-trips exactly to the scheme's fake-quant output.
#[test]
fn prop_pack_roundtrip() {
    let mut rng = SplitMix64::new(6);
    for _ in 0..CASES / 2 {
        let x = arb_matrix(&mut rng);
        let bits = if rng.uniform() < 0.5 { Bits::Int4 } else { Bits::Int8 };
        let alpha = arb_alpha(&mut rng);
        let q = CrossQuant::new(alpha, bits);
        let packed = PackedMatrix::pack(&x, &q);
        let unpacked = packed.unpack();
        let fq = q.fake_quant(&x);
        for (u, v) in unpacked.data.iter().zip(&fq.data) {
            assert!((u - v).abs() <= 1e-5 * u.abs().max(1e-3), "{u} vs {v}");
        }
    }
}

/// Group-wise fake-quant preserves shape and never increases any group's
/// absolute maximum.
#[test]
fn prop_groupwise_preserves_shape_and_max() {
    let mut rng = SplitMix64::new(7);
    for _ in 0..CASES {
        let x = arb_matrix(&mut rng);
        let group = 1 + rng.below(40);
        let g = GroupWise::new(Bits::Int4, group);
        let q = g.fake_quant(&x);
        assert_eq!((q.rows, q.cols), (x.rows, x.cols));
        let max_in = x.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let max_out = q.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(max_out <= max_in * 1.0001);
    }
}

/// RemoveKernel with θ = 0.5/qmax zeroes exactly the per-token kernel.
#[test]
fn prop_remove_kernel_matches_per_token_kernel() {
    let mut rng = SplitMix64::new(8);
    for _ in 0..CASES {
        let x = arb_matrix(&mut rng);
        let bits = arb_bits(&mut rng);
        let qmax = bits.qmax();
        let removed = RemoveKernel::matching_per_token(qmax).apply(&x);
        let quantized = PerToken::new(bits).fake_quant(&x);
        for idx in 0..x.len() {
            if x.data[idx] != 0.0 {
                assert_eq!(
                    removed.data[idx] == 0.0,
                    quantized.data[idx] == 0.0,
                    "idx {idx} x={}",
                    x.data[idx]
                );
            }
        }
    }
}

/// The quantization kernel shrinks (weakly) as α decreases on matrices
/// whose column maxima sit below row maxima (the paper's argument for why
/// smaller α helps under outliers).
#[test]
fn prop_kernel_weakly_monotone_in_alpha_under_outliers() {
    let mut rng = SplitMix64::new(9);
    for _ in 0..CASES / 2 {
        let rows = 8 + rng.below(40);
        let cols = 8 + rng.below(40);
        let mut x = Matrix::randn(rows, cols, 1.0, &mut rng);
        let j = rng.below(cols);
        for i in 0..rows {
            let v = x.get(i, j);
            x.set(i, j, v * 60.0); // every row's max lives in column j
        }
        let k = |alpha: f32| {
            kernel_fraction(&x, &CrossQuant::new(alpha, Bits::Int8).delta_field(&x))
        };
        let (k15, k55, k100) = (k(0.15), k(0.55), k(1.0));
        assert!(k15 <= k55 + 0.02, "k15={k15} k55={k55}");
        assert!(k55 <= k100 + 0.02, "k55={k55} k100={k100}");
    }
}

/// SmoothQuant's migration is exactly function-preserving before
/// quantization: (X/s)·(diag(s)W) == X·W.
#[test]
fn prop_smoothquant_function_preserving() {
    use crossquant::quant::smoothquant::SmoothQuant;
    let mut rng = SplitMix64::new(10);
    for _ in 0..60 {
        let rows = 4 + rng.below(40);
        let inner = 2 + rng.below(30);
        let cols = 2 + rng.below(30);
        let x = arb_matrix_shaped(&mut rng, rows, inner);
        let w = Matrix::randn(inner, cols, 0.1, &mut rng);
        let strength = (rng.uniform() as f32).clamp(0.05, 0.95);
        let sq = SmoothQuant::calibrate(&x, &w, strength);
        let y = x.matmul(&w);
        let y2 = sq.smooth_activation(&x).matmul(&sq.fold_into_weight(&w));
        let rel = y.distance(&y2) / y.frobenius().max(1e-6);
        assert!(rel < 1e-4, "strength {strength} rel {rel}");
    }
}

/// AWQ's effective weight never loses to plain group-wise quantization on
/// its own calibration data (the grid includes β = 0 ≡ plain).
#[test]
fn prop_awq_no_worse_than_plain_groupwise() {
    use crossquant::quant::awq::Awq;
    let mut rng = SplitMix64::new(11);
    for _ in 0..30 {
        let rows = 16 + rng.below(48);
        let inner = 8 + rng.below(24);
        let cols = 4 + rng.below(16);
        let x = arb_matrix_shaped(&mut rng, rows, inner);
        let w = Matrix::randn(inner, cols, 0.1, &mut rng);
        let group = 8;
        let y_ref = x.matmul(&w);
        let plain = GroupWise::new(Bits::Int4, group).fake_quant(&w);
        let e_plain = y_ref.distance(&x.matmul(&plain));
        let awq = Awq::search(&x, &w, Bits::Int4, group);
        let e_awq = y_ref.distance(&awq.smooth_activation(&x).matmul(&awq.quantize_weight(&w)));
        assert!(e_awq <= e_plain * 1.001, "awq {e_awq} plain {e_plain}");
    }
}

/// Quantization never increases a matrix's absolute maximum (symmetric
/// clipping can only shrink).
#[test]
fn prop_quantization_never_amplifies_max() {
    let mut rng = SplitMix64::new(12);
    for _ in 0..CASES {
        let x = arb_matrix(&mut rng);
        let alpha = arb_alpha(&mut rng);
        let bits = arb_bits(&mut rng);
        let q = CrossQuant::new(alpha, bits).fake_quant(&x);
        let max_in = x.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let max_out = q.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(max_out <= max_in * 1.0001, "in {max_in} out {max_out}");
    }
}

fn arb_matrix_shaped(rng: &mut SplitMix64, rows: usize, cols: usize) -> Matrix {
    let mut x = Matrix::randn(rows, cols, 1.0, rng);
    if rng.uniform() < 0.5 {
        let j = rng.below(cols);
        for i in 0..rows {
            let v = x.get(i, j) * 30.0;
            x.set(i, j, v);
        }
    }
    x
}
