//! TCP eval-server integration: spin the server on an ephemeral port, talk
//! the line protocol from a client socket. Skips without artifacts.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{EvalCoordinator, EvalServer};
use crossquant::corpus::CorpusGen;
use crossquant::runtime::ArtifactStore;
use crossquant::util::Json;

fn start_server() -> Option<(std::net::SocketAddr, crossquant::model::ModelConfig)> {
    let store = ArtifactStore::discover(None).ok()?;
    store.validate().ok()?;
    let weights = store.load_weights().ok()?;
    let cfg = weights.config;
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: cfg.eval_batch,
            max_batch_delay: Duration::from_millis(3),
            max_queue: 64,
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").ok()?;
    let addr = listener.local_addr().ok()?;
    std::thread::spawn(move || {
        let _ = EvalServer::new(coordinator).serve(listener);
    });
    Some((addr, cfg))
}

fn roundtrip(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line).expect("server must emit valid JSON")
}

#[test]
fn serves_eval_requests_over_tcp() {
    let Some((addr, cfg)) = start_server() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // ping
    let pong = roundtrip(&mut stream, &mut reader, r#"{"cmd": "ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    // a crossquant eval request
    let toks = CorpusGen::new(cfg.vocab, 3).sequence(cfg.seq_len);
    let toks_json: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
    let req = format!(
        r#"{{"tokens": [{}], "scheme": "crossquant", "alpha": 0.15, "weight_set": "w16"}}"#,
        toks_json.join(", ")
    );
    let resp = roundtrip(&mut stream, &mut reader, &req);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    assert_eq!(resp.get("nll").unwrap().as_arr().unwrap().len(), cfg.seq_len - 1);
    let ppl = resp.get("ppl").unwrap().as_f64().unwrap();
    assert!(ppl > 1.0 && ppl < 10.0 * cfg.vocab as f64, "ppl {ppl}");
    let aux = resp.get("aux").unwrap().as_f64().unwrap();
    assert!(aux > 0.0 && aux < 1.0);

    // bad scheme → structured error, connection stays up
    let err = roundtrip(&mut stream, &mut reader, r#"{"tokens": [1,2,3], "scheme": "nope"}"#);
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
    assert!(err.get("error").unwrap().as_str().unwrap().contains("scheme"));

    // metrics still served afterwards
    let m = roundtrip(&mut stream, &mut reader, r#"{"cmd": "metrics"}"#);
    assert!(m.get("metrics").unwrap().as_str().unwrap().contains("completed="));
}

#[test]
fn concurrent_clients_share_batches() {
    let Some((addr, cfg)) = start_server() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let n_clients = cfg.eval_batch;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let toks = CorpusGen::new(cfg.vocab, 10 + i as u64).sequence(cfg.seq_len);
                let tj: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
                let req = format!(
                    r#"{{"tokens": [{}], "scheme": "per-token", "weight_set": "w16"}}"#,
                    tj.join(",")
                );
                let resp = roundtrip(&mut stream, &mut reader, &req);
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
