//! TCP eval-server integration: spin the server on an ephemeral port, talk
//! the line protocol from a client socket. The artifact-backed tests skip
//! without artifacts; the synthetic-weights tests (generation protocol)
//! run everywhere through the native-executor fallback.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{EvalCoordinator, EvalServer};
use crossquant::corpus::CorpusGen;
use crossquant::model::weights::synthetic_weights;
use crossquant::model::ModelConfig;
use crossquant::runtime::ArtifactStore;
use crossquant::util::Json;

fn start_server() -> Option<(std::net::SocketAddr, crossquant::model::ModelConfig)> {
    let store = ArtifactStore::discover(None).ok()?;
    store.validate().ok()?;
    let weights = store.load_weights().ok()?;
    let cfg = weights.config;
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: cfg.eval_batch,
            max_batch_delay: Duration::from_millis(3),
            max_queue: 64,
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").ok()?;
    let addr = listener.local_addr().ok()?;
    std::thread::spawn(move || {
        let _ = EvalServer::new(coordinator).serve(listener);
    });
    Some((addr, cfg))
}

/// A server over synthetic weights and a directory holding only a
/// manifest: no artifacts anywhere, so the coordinator's native executor
/// serves every request — runs on every build.
fn start_synthetic_server() -> (std::net::SocketAddr, ModelConfig) {
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    };
    let dir = std::env::temp_dir().join(format!(
        "cq-server-{}-{:?}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let weights = synthetic_weights(cfg, 23);
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir },
        cfg,
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 16,
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = EvalServer::new(coordinator).serve(listener);
    });
    (addr, cfg)
}

fn roundtrip(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line).expect("server must emit valid JSON")
}

#[test]
fn serves_eval_requests_over_tcp() {
    let Some((addr, cfg)) = start_server() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // ping
    let pong = roundtrip(&mut stream, &mut reader, r#"{"cmd": "ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    // a crossquant eval request
    let toks = CorpusGen::new(cfg.vocab, 3).sequence(cfg.seq_len);
    let toks_json: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
    let req = format!(
        r#"{{"tokens": [{}], "scheme": "crossquant", "alpha": 0.15, "weight_set": "w16"}}"#,
        toks_json.join(", ")
    );
    let resp = roundtrip(&mut stream, &mut reader, &req);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    assert_eq!(resp.get("nll").unwrap().as_arr().unwrap().len(), cfg.seq_len - 1);
    let ppl = resp.get("ppl").unwrap().as_f64().unwrap();
    assert!(ppl > 1.0 && ppl < 10.0 * cfg.vocab as f64, "ppl {ppl}");
    let aux = resp.get("aux").unwrap().as_f64().unwrap();
    assert!(aux > 0.0 && aux < 1.0);

    // bad scheme → structured error, connection stays up
    let err = roundtrip(&mut stream, &mut reader, r#"{"tokens": [1,2,3], "scheme": "nope"}"#);
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
    assert!(err.get("error").unwrap().as_str().unwrap().contains("scheme"));

    // metrics still served afterwards
    let m = roundtrip(&mut stream, &mut reader, r#"{"cmd": "metrics"}"#);
    assert!(m.get("metrics").unwrap().as_str().unwrap().contains("completed="));
}

#[test]
fn generate_round_trips_over_tcp_for_every_scheme() {
    let (addr, cfg) = start_synthetic_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    for scheme in ["fp", "crossquant", "crossquant-static"] {
        let prompt = CorpusGen::new(cfg.vocab, 7).sequence(4);
        let pj: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
        let req = format!(
            r#"{{"tokens": [{}], "scheme": "{scheme}", "alpha": 0.15, "max_new_tokens": 6, "weight_set": "w16"}}"#,
            pj.join(", ")
        );
        let resp = roundtrip(&mut stream, &mut reader, &req);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{scheme}: {resp:?}");
        let generated = resp.get("generated").unwrap().as_arr().unwrap();
        assert_eq!(generated.len(), 6, "{scheme}");
        assert!(
            generated.iter().all(|t| t.as_usize().is_some_and(|v| v < cfg.vocab)),
            "{scheme}: generated ids must be in-vocab"
        );
        assert_eq!(resp.get("prompt_tokens").unwrap().as_usize(), Some(4));
        // greedy decode is deterministic: the same request replays exactly
        let again = roundtrip(&mut stream, &mut reader, &req);
        assert_eq!(again.get("generated"), resp.get("generated"), "{scheme}");
    }
}

#[test]
fn generate_context_overflow_is_a_structured_protocol_error() {
    let (addr, cfg) = start_synthetic_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // prompt 8 + 5 new tokens > n_ctx 12: a structured error, no panic
    let prompt = CorpusGen::new(cfg.vocab, 9).sequence(8);
    let pj: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let req = format!(
        r#"{{"tokens": [{}], "scheme": "fp", "max_new_tokens": 5, "weight_set": "w16"}}"#,
        pj.join(", ")
    );
    let resp = roundtrip(&mut stream, &mut reader, &req);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
    let err = resp.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("exceeds model context"), "unexpected error: {err}");

    // the connection survives and a well-formed request still succeeds
    let ok_req = format!(
        r#"{{"tokens": [{}], "scheme": "fp", "max_new_tokens": 4, "weight_set": "w16"}}"#,
        pj.join(", ")
    );
    let ok = roundtrip(&mut stream, &mut reader, &ok_req);
    assert_eq!(ok.get("ok"), Some(&Json::Bool(true)), "{ok:?}");
    assert_eq!(ok.get("generated").unwrap().as_arr().unwrap().len(), 4);
}

#[test]
fn concurrent_clients_share_batches() {
    let Some((addr, cfg)) = start_server() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let n_clients = cfg.eval_batch;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let toks = CorpusGen::new(cfg.vocab, 10 + i as u64).sequence(cfg.seq_len);
                let tj: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
                let req = format!(
                    r#"{{"tokens": [{}], "scheme": "per-token", "weight_set": "w16"}}"#,
                    tj.join(",")
                );
                let resp = roundtrip(&mut stream, &mut reader, &req);
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
