//! TCP eval-server integration: spin the server on an ephemeral port, talk
//! the line protocol from a client socket. The artifact-backed tests skip
//! without artifacts; the synthetic-weights tests (generation protocol)
//! run everywhere through the native-executor fallback.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{EvalCoordinator, EvalServer};
use crossquant::corpus::CorpusGen;
use crossquant::model::weights::synthetic_weights;
use crossquant::model::ModelConfig;
use crossquant::runtime::ArtifactStore;
use crossquant::util::Json;

fn start_server() -> Option<(std::net::SocketAddr, crossquant::model::ModelConfig)> {
    let store = ArtifactStore::discover(None).ok()?;
    store.validate().ok()?;
    let weights = store.load_weights().ok()?;
    let cfg = weights.config;
    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: cfg.eval_batch,
            max_batch_delay: Duration::from_millis(3),
            max_queue: 64,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").ok()?;
    let addr = listener.local_addr().ok()?;
    std::thread::spawn(move || {
        let _ = EvalServer::new(coordinator).serve(listener);
    });
    Some((addr, cfg))
}

/// A server over synthetic weights and a directory holding only a
/// manifest: no artifacts anywhere, so the coordinator's native executor
/// serves every request — runs on every build.
fn start_synthetic_server() -> (std::net::SocketAddr, ModelConfig) {
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    };
    let dir = std::env::temp_dir().join(format!(
        "cq-server-{}-{:?}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let weights = synthetic_weights(cfg, 23);
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir },
        cfg,
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 16,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = EvalServer::new(coordinator).serve(listener);
    });
    (addr, cfg)
}

fn roundtrip(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(&line).expect("server must emit valid JSON")
}

#[test]
fn serves_eval_requests_over_tcp() {
    let Some((addr, cfg)) = start_server() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // ping
    let pong = roundtrip(&mut stream, &mut reader, r#"{"cmd": "ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    // a crossquant eval request
    let toks = CorpusGen::new(cfg.vocab, 3).sequence(cfg.seq_len);
    let toks_json: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
    let req = format!(
        r#"{{"tokens": [{}], "scheme": "crossquant", "alpha": 0.15, "weight_set": "w16"}}"#,
        toks_json.join(", ")
    );
    let resp = roundtrip(&mut stream, &mut reader, &req);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    assert_eq!(resp.get("nll").unwrap().as_arr().unwrap().len(), cfg.seq_len - 1);
    let ppl = resp.get("ppl").unwrap().as_f64().unwrap();
    assert!(ppl > 1.0 && ppl < 10.0 * cfg.vocab as f64, "ppl {ppl}");
    let aux = resp.get("aux").unwrap().as_f64().unwrap();
    assert!(aux > 0.0 && aux < 1.0);

    // bad scheme → structured error, connection stays up
    let err = roundtrip(&mut stream, &mut reader, r#"{"tokens": [1,2,3], "scheme": "nope"}"#);
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
    assert!(err.get("error").unwrap().as_str().unwrap().contains("scheme"));

    // metrics still served afterwards
    let m = roundtrip(&mut stream, &mut reader, r#"{"cmd": "metrics"}"#);
    assert!(m.get("metrics").unwrap().as_str().unwrap().contains("completed="));
}

#[test]
fn generate_round_trips_over_tcp_for_every_scheme() {
    let (addr, cfg) = start_synthetic_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // the full wire-servable registry surface: FP, both dynamic
    // quantizers, and every registry-built static scheme
    for scheme in [
        "fp",
        "per-token",
        "crossquant",
        "crossquant-static",
        "smoothquant",
        "awq",
        "gptq",
        "lorc",
    ] {
        let prompt = CorpusGen::new(cfg.vocab, 7).sequence(4);
        let pj: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
        let req = format!(
            r#"{{"tokens": [{}], "scheme": "{scheme}", "alpha": 0.15, "max_new_tokens": 6, "weight_set": "w16"}}"#,
            pj.join(", ")
        );
        let resp = roundtrip(&mut stream, &mut reader, &req);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{scheme}: {resp:?}");
        let generated = resp.get("generated").unwrap().as_arr().unwrap();
        assert_eq!(generated.len(), 6, "{scheme}");
        assert!(
            generated.iter().all(|t| t.as_usize().is_some_and(|v| v < cfg.vocab)),
            "{scheme}: generated ids must be in-vocab"
        );
        assert_eq!(resp.get("prompt_tokens").unwrap().as_usize(), Some(4));
        // greedy decode is deterministic: the same request replays exactly
        let again = roundtrip(&mut stream, &mut reader, &req);
        assert_eq!(again.get("generated"), resp.get("generated"), "{scheme}");
    }
}

#[test]
fn generate_context_overflow_is_a_structured_protocol_error() {
    let (addr, cfg) = start_synthetic_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // prompt 8 + 5 new tokens > n_ctx 12: a structured error, no panic
    let prompt = CorpusGen::new(cfg.vocab, 9).sequence(8);
    let pj: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let req = format!(
        r#"{{"tokens": [{}], "scheme": "fp", "max_new_tokens": 5, "weight_set": "w16"}}"#,
        pj.join(", ")
    );
    let resp = roundtrip(&mut stream, &mut reader, &req);
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp:?}");
    let err = resp.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("exceeds model context"), "unexpected error: {err}");

    // the connection survives and a well-formed request still succeeds
    let ok_req = format!(
        r#"{{"tokens": [{}], "scheme": "fp", "max_new_tokens": 4, "weight_set": "w16"}}"#,
        pj.join(", ")
    );
    let ok = roundtrip(&mut stream, &mut reader, &ok_req);
    assert_eq!(ok.get("ok"), Some(&Json::Bool(true)), "{ok:?}");
    assert_eq!(ok.get("generated").unwrap().as_arr().unwrap().len(), 4);
}

#[test]
fn streamed_generation_emits_token_lines_then_summary() {
    let (addr, cfg) = start_synthetic_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let prompt = CorpusGen::new(cfg.vocab, 11).sequence(3);
    let pj: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let req = format!(
        r#"{{"tokens": [{}], "scheme": "crossquant", "alpha": 0.15, "max_new_tokens": 5, "stream": true, "weight_set": "w16"}}"#,
        pj.join(", ")
    );
    stream.write_all(req.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();

    // exactly max_new_tokens token lines, then the summary line
    let mut tokens = Vec::new();
    let summary = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).expect("stream lines must be valid JSON");
        if let Some(t) = j.get("token") {
            assert!(j.get("seq").and_then(|s| s.as_usize()).is_some(), "token lines carry seq");
            tokens.push(t.as_usize().unwrap() as u32);
        } else {
            break j;
        }
    };
    assert_eq!(tokens.len(), 5);
    assert_eq!(summary.get("ok"), Some(&Json::Bool(true)), "{summary:?}");
    assert_eq!(summary.get("done"), Some(&Json::Bool(true)));
    let generated: Vec<u32> = summary
        .get("generated")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(generated, tokens, "summary must repeat the streamed tokens");
    assert_eq!(summary.get("prompt_tokens").unwrap().as_usize(), Some(3));

    // the same request unstreamed is bit-identical — the engine serves both
    let plain = roundtrip(
        &mut stream,
        &mut reader,
        &format!(
            r#"{{"tokens": [{}], "scheme": "crossquant", "alpha": 0.15, "max_new_tokens": 5, "weight_set": "w16"}}"#,
            pj.join(", ")
        ),
    );
    assert_eq!(plain.get("generated"), summary.get("generated"));

    // streaming a scoring request is a structured error, connection survives
    let err = roundtrip(
        &mut stream,
        &mut reader,
        r#"{"tokens": [1,2,3], "scheme": "fp", "stream": true, "weight_set": "w16"}"#,
    );
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
    assert!(err.get("error").unwrap().as_str().unwrap().contains("max_new_tokens"));
}

#[test]
fn metrics_report_engine_and_kv_pool_accounting() {
    let (addr, cfg) = start_synthetic_server();
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // run one generation so the engine counters are non-trivial
    let prompt = CorpusGen::new(cfg.vocab, 13).sequence(3);
    let pj: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let gen = roundtrip(
        &mut stream,
        &mut reader,
        &format!(
            r#"{{"tokens": [{}], "scheme": "fp", "max_new_tokens": 4, "weight_set": "w16"}}"#,
            pj.join(", ")
        ),
    );
    assert_eq!(gen.get("ok"), Some(&Json::Bool(true)), "{gen:?}");

    let m = roundtrip(&mut stream, &mut reader, r#"{"cmd": "metrics"}"#);
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)));
    // the summary string survives unchanged…
    assert!(m.get("metrics").unwrap().as_str().unwrap().contains("completed="));
    // …and the engine object surfaces KV memory accounting over the wire
    let engine = m.get("engine").expect("engine metrics object");
    let kv = engine.get("kv_pool").expect("kv_pool object");
    let slot_bytes = kv.get("bytes_per_seq").unwrap().as_f64().unwrap();
    // 2 (K+V) · n_layers · n_ctx · d_model · 4 bytes, from the model config
    let expect = (2 * cfg.n_layers * cfg.seq_len * cfg.d_model * 4) as f64;
    assert_eq!(slot_bytes, expect);
    assert!(kv.get("bytes").unwrap().as_f64().unwrap() >= expect);
    assert_eq!(kv.get("slots_in_use").unwrap().as_f64(), Some(0.0));
    let decoded = engine.get("decoded_tokens").unwrap().as_f64().unwrap();
    // 4 generated tokens: 1 sampled at prefill + 3 batched decode steps
    assert_eq!(decoded, 3.0);
    assert!(engine.get("batch_occupancy").unwrap().as_f64().unwrap() >= 1.0);
}

#[test]
fn connection_cap_refuses_excess_clients_with_structured_error() {
    // a server capped at 1 connection, built by hand (the helper uses the
    // default cap)
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    };
    let dir = std::env::temp_dir().join(format!(
        "cq-conncap-{}-{:?}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let weights = synthetic_weights(cfg, 29);
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir },
        cfg,
        vec![("w16".into(), weights.flat.clone())],
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 16,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = EvalServer::new(coordinator).with_max_connections(1).serve(listener);
    });

    // first client occupies the only slot (a ping proves it is registered)
    let mut first = TcpStream::connect(addr).unwrap();
    first.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut first_reader = BufReader::new(first.try_clone().unwrap());
    let pong = roundtrip(&mut first, &mut first_reader, r#"{"cmd": "ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    // second client is refused with the structured capacity error
    let second = TcpStream::connect(addr).unwrap();
    second.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
    let mut second_reader = BufReader::new(second);
    let mut line = String::new();
    second_reader.read_line(&mut line).unwrap();
    let refusal = Json::parse(&line).expect("refusal must be valid JSON");
    assert_eq!(refusal.get("ok"), Some(&Json::Bool(false)));
    assert!(refusal
        .get("error")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("connection capacity"));
    // …and the socket is closed after the error line
    line.clear();
    assert_eq!(second_reader.read_line(&mut line).unwrap(), 0, "refused socket must close");

    // once the first client disconnects, a new one is admitted
    drop(first_reader);
    drop(first);
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    loop {
        let mut third = TcpStream::connect(addr).unwrap();
        third.set_read_timeout(Some(Duration::from_secs(300))).unwrap();
        let mut third_reader = BufReader::new(third.try_clone().unwrap());
        third.write_all(b"{\"cmd\": \"ping\"}\n").unwrap();
        let mut resp = String::new();
        third_reader.read_line(&mut resp).unwrap();
        let j = Json::parse(&resp).unwrap();
        if j.get("ok") == Some(&Json::Bool(true)) && j.get("pong").is_some() {
            break; // admitted again
        }
        assert!(
            std::time::Instant::now() < deadline,
            "slot must free after the first client disconnects"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn concurrent_clients_share_batches() {
    let Some((addr, cfg)) = start_server() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let n_clients = cfg.eval_batch;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let toks = CorpusGen::new(cfg.vocab, 10 + i as u64).sequence(cfg.seq_len);
                let tj: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
                let req = format!(
                    r#"{{"tokens": [{}], "scheme": "per-token", "weight_set": "w16"}}"#,
                    tj.join(",")
                );
                let resp = roundtrip(&mut stream, &mut reader, &req);
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
