//! Scheme-registry conformance suite: every registered scheme must
//! round-trip through the `.cqa` artifact bit-identically, be selectable
//! through the coordinator, decode identically under the
//! continuous-batching engine and solo, and — for the schemes migrated
//! off the old scattered match arms — serve the same NLLs as the
//! pre-refactor paths they replaced.

use std::path::PathBuf;
use std::time::Duration;

use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{ActScheme, EvalCoordinator, EvalRequest};
use crossquant::corpus::CorpusGen;
use crossquant::model::weights::{synthetic_weights, Weights};
use crossquant::model::{IdentitySite, ModelConfig, NativeModel, QuantSite, QuantizedModel};
use crossquant::quant::artifact::Artifact;
use crossquant::quant::crossquant::CrossQuant;
use crossquant::quant::registry::{self, SchemeId, StaticSpec, ALL};
use crossquant::quant::Bits;
use crossquant::runtime::ArtifactStore;

fn cfg() -> ModelConfig {
    ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        seq_len: 12,
        eval_batch: 2,
    }
}

fn base_weights() -> Weights {
    synthetic_weights(cfg(), 23)
}

/// The scheduler's FP-path calibration stream (8 sequences, seed
/// 0x5CA1E) — references built on it match the served models exactly.
fn serving_calib() -> Vec<Vec<u32>> {
    let c = cfg();
    let mut gen = CorpusGen::new(c.vocab, 0x5CA1E);
    (0..8).map(|_| gen.sequence(c.seq_len)).collect()
}

fn probe() -> Vec<u32> {
    let c = cfg();
    (0..c.seq_len).map(|i| ((i * 7) % c.vocab) as u32).collect()
}

fn static_schemes() -> Vec<(SchemeId, usize)> {
    ALL.into_iter()
        .filter(|id| id.is_static())
        .map(|id| (id, if id == SchemeId::Lorc { 4 } else { 0 }))
        .collect()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "cq-registry-{tag}-{}-{:?}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn start_coordinator(weight_sets: Vec<(String, Vec<f32>)>) -> EvalCoordinator {
    EvalCoordinator::start(
        ArtifactStore { dir: temp_dir("store") },
        cfg(),
        weight_sets,
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 32,
            engine: Default::default(),
            artifacts: Vec::new(),
        },
    )
}

#[test]
fn every_registered_scheme_round_trips_its_artifact_bit_identically() {
    let w = base_weights();
    let calib = serving_calib();
    let dir = temp_dir("artifacts");
    for (id, rank) in static_schemes() {
        let spec = StaticSpec::new(id, 0.15, rank);
        let qm = registry::build_static_model(&w, Bits::Int8, Bits::Int8, &spec, &calib)
            .unwrap_or_else(|e| panic!("{id}: {e:#}"));
        let path = dir.join(format!("{}.cqa", id.name()));
        qm.write_artifact(&path).unwrap();

        // the header carries the scheme id, readable without a model
        let art = Artifact::open(&path).unwrap();
        assert_eq!(art.scheme, id.artifact_code(), "{id}");

        // the loaded model serves bit-identical NLLs and keeps its scheme
        let loaded = QuantizedModel::load_artifact(&path).unwrap();
        assert_eq!(loaded.scheme_code, id.artifact_code(), "{id}");
        assert_eq!(
            qm.forward_nll(&probe()).unwrap(),
            loaded.forward_nll(&probe()).unwrap(),
            "{id}: artifact load must not perturb serving"
        );

        // resave byte-identity: load → write is a fixed point
        let resave = dir.join(format!("{}-resave.cqa", id.name()));
        loaded.write_artifact(&resave).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&resave).unwrap(),
            "{id}: resave must be byte-identical"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn migrated_schemes_serve_the_pre_refactor_nlls() {
    let w = base_weights();
    let coordinator = start_coordinator(vec![("w16".into(), w.flat.clone())]);
    let toks = probe();

    // fp: bit-identical to the plain native forward
    let fp = coordinator
        .submit(EvalRequest::score(toks.clone(), ActScheme::Fp, "w16"))
        .unwrap()
        .wait()
        .unwrap();
    let native = NativeModel::new(w.clone());
    assert_eq!(fp.nll, native.forward_nll(&toks, &mut IdentitySite).unwrap());

    // crossquant-static: bit-identical to the registry build on the
    // scheduler's calibration stream (the historical calibrate_static path)
    let st = coordinator
        .submit(EvalRequest::score(
            toks.clone(),
            ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 },
            "w16",
        ))
        .unwrap()
        .wait()
        .unwrap();
    let reference = registry::build_static_model(
        &w,
        Bits::Int8,
        Bits::Int8,
        &StaticSpec::new(SchemeId::CrossQuantStatic, 0.15, 0),
        &serving_calib(),
    )
    .unwrap();
    assert_eq!(st.nll, reference.forward_nll(&toks).unwrap());

    // dynamic crossquant (and per-token at α = 1): the served NLL tracks
    // the library quantizer to float tolerance
    for alpha in [0.15f32, 1.0] {
        let served = coordinator
            .submit(EvalRequest::score(
                toks.clone(),
                ActScheme::CrossQuant { alpha, qmax: 127.0 },
                "w16",
            ))
            .unwrap()
            .wait()
            .unwrap();
        let mut site = QuantSite::new(CrossQuant::new(alpha, Bits::Int8));
        let expect = native.forward_nll(&toks, &mut site).unwrap();
        for (a, b) in served.nll.iter().zip(&expect) {
            assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "α={alpha}: {a} vs {b}");
        }
    }
    coordinator.shutdown();
}

#[test]
fn engine_decode_matches_solo_decode_for_every_static_scheme() {
    let w = base_weights();
    let coordinator = start_coordinator(vec![("w16".into(), w.flat.clone())]);
    let prompt = vec![2u32, 3, 4];
    for (id, rank) in static_schemes() {
        let scheme = match id {
            SchemeId::CrossQuantStatic => ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 },
            SchemeId::SmoothQuant => ActScheme::SmoothQuant { alpha: 0.15, qmax: 127.0 },
            SchemeId::Awq => ActScheme::Awq { alpha: 0.15, qmax: 127.0 },
            SchemeId::Gptq => ActScheme::Gptq { alpha: 0.15, qmax: 127.0 },
            SchemeId::Lorc => ActScheme::Lorc { alpha: 0.15, rank, qmax: 127.0 },
            other => panic!("{other} is not static"),
        };
        let served = coordinator
            .submit(EvalRequest::generate(prompt.clone(), scheme, "w16", 5))
            .unwrap()
            .wait()
            .unwrap_or_else(|e| panic!("{id}: {e:#}"));
        let solo = registry::build_static_model(
            &w,
            Bits::Int8,
            Bits::Int8,
            &StaticSpec::new(id, 0.15, rank),
            &serving_calib(),
        )
        .unwrap()
        .generate_greedy(&prompt, 5)
        .unwrap();
        assert_eq!(served.generated, solo, "{id}: engine and solo decode must agree");
    }
    coordinator.shutdown();
}

#[test]
fn mounted_artifact_serves_only_its_own_scheme() {
    let w = base_weights();
    let calib = serving_calib();
    let dir = temp_dir("mount");
    let spec = StaticSpec::new(SchemeId::Gptq, 0.15, 0);
    let reference =
        registry::build_static_model(&w, Bits::Int8, Bits::Int8, &spec, &calib).unwrap();
    let apath = dir.join("gptq.cqa");
    reference.write_artifact(&apath).unwrap();

    // artifact-only coordinator: no FP weight sets at all
    let coordinator = EvalCoordinator::start(
        ArtifactStore { dir: dir.clone() },
        cfg(),
        Vec::new(),
        CoordinatorConfig {
            batch_size: 2,
            max_batch_delay: Duration::from_millis(2),
            max_queue: 32,
            engine: Default::default(),
            artifacts: vec![("w16".into(), apath)],
        },
    );
    let toks = probe();

    // the artifact's own scheme is served straight off the mapping,
    // bit-identical to the model that wrote it
    let served = coordinator
        .submit(EvalRequest::score(
            toks.clone(),
            ActScheme::Gptq { alpha: 0.15, qmax: 127.0 },
            "w16",
        ))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(served.nll, reference.forward_nll(&toks).unwrap());

    // any other scheme against the mount needs FP weights → structured
    // artifact-only refusal
    let err = coordinator
        .submit(EvalRequest::score(
            toks,
            ActScheme::CrossQuantStatic { alpha: 0.15, qmax: 127.0 },
            "w16",
        ))
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(err.to_string().contains("artifact-only"), "{err:#}");
    coordinator.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wire_names_cover_the_whole_registry() {
    for id in ALL {
        assert_eq!(id.name().parse::<SchemeId>().unwrap(), id, "{id}");
    }
    assert!("bogus".parse::<SchemeId>().unwrap_err().to_string().contains("unknown scheme"));
}
