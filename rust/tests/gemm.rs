//! Property tests for the packed-panel int8 GEMM and the static-scale
//! CrossQuant deployment path (hand-rolled randomized driver — the
//! offline build has no proptest; see Cargo.toml).
//!
//! The packed kernel must be *bit-exact* against the naive i32 triple
//! loop for every shape and worker count: integer accumulation is
//! order-independent, so there is no tolerance anywhere in these
//! comparisons. CI runs this file in release mode as well (optimized
//! codegen exercises the vectorized microkernel paths).

use crossquant::model::weights::synthetic_weights;
use crossquant::model::{ModelConfig, QuantPath, QuantizedModel};
use crossquant::quant::crossquant::col_pow_scales;
use crossquant::quant::gemm::{
    dispatch, gemm_dequant, gemm_i32_packed, gemm_i32_packed_isa, gemm_i32_ref, Isa, PackedInt8,
    KB, MR, NR,
};
use crossquant::quant::qlinear::{QuantizedLinear, ScaleMode};
use crossquant::quant::Bits;
use crossquant::tensor::{Matrix, SplitMix64};

const WORKER_GRID: [usize; 4] = [1, 2, 5, 16];

/// Random codes with a controllable zero fraction (the quantization
/// kernel) — exercises both the dense path and the zero-block skip.
fn arb_codes(rng: &mut SplitMix64, len: usize, zero_frac: f64) -> Vec<i8> {
    (0..len)
        .map(|_| {
            if rng.uniform() < zero_frac {
                0i8
            } else {
                (rng.below(255) as i64 - 127) as i8
            }
        })
        .collect()
}

/// Every ISA this host can actually execute — scalar always, plus the
/// native vector path. ISAs the host cannot run are covered by the
/// loud-panic tests in `quant::gemm::dispatch` instead.
fn isas_under_test() -> Vec<Isa> {
    Isa::ALL.into_iter().filter(|&isa| dispatch::supported(isa)).collect()
}

fn check_shape(rng: &mut SplitMix64, m: usize, k: usize, n: usize, zero_frac: f64) {
    let a = arb_codes(rng, m * k, zero_frac);
    let w = arb_codes(rng, k * n, 0.1);
    let packed = PackedInt8::from_row_major(&w, k, n);
    let reference = gemm_i32_ref(&a, m, k, &w, n);
    for workers in WORKER_GRID {
        assert_eq!(
            gemm_i32_packed(&a, m, &packed, workers),
            reference,
            "m={m} k={k} n={n} zero={zero_frac:.2} workers={workers}"
        );
    }
    // every supported dispatch path must agree bit-for-bit, serial and tiled
    for isa in isas_under_test() {
        for workers in [1usize, 5] {
            assert_eq!(
                gemm_i32_packed_isa(&a, m, &packed, workers, isa),
                reference,
                "isa={isa} m={m} k={k} n={n} zero={zero_frac:.2} workers={workers}"
            );
        }
    }
}

/// Random shapes crossing every tiling boundary (MR row groups, NR
/// panels, KB zero-skip blocks), random sparsity.
#[test]
fn prop_packed_gemm_bit_exact_vs_naive() {
    let mut rng = SplitMix64::new(0xC1);
    for _ in 0..40 {
        let m = 1 + rng.below(6 * MR);
        let k = rng.below(3 * KB);
        let n = 1 + rng.below(6 * NR);
        let zero_frac = rng.uniform();
        check_shape(&mut rng, m, k, n, zero_frac);
    }
}

/// The shapes where the tiling logic can go wrong, enumerated.
#[test]
fn packed_gemm_edge_shapes() {
    let mut rng = SplitMix64::new(0xC2);
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),                            // minimal
        (MR - 1, KB, NR - 1),                 // remainder row group + remainder panel
        (MR, KB, NR),                         // exact single tiles
        (MR + 1, KB + 1, NR + 1),             // one past every boundary
        (2 * MR + 3, 2 * KB + 7, 3 * NR + 5), // interior + remainders
        (5, 0, 3),                            // K = 0: empty contraction
        (1, 3 * KB, 2 * NR),                  // single row, many k-blocks
        (3 * MR, 1, 1),                       // single column, single depth
    ];
    for &(m, k, n) in shapes {
        for zero_frac in [0.0, 0.5, 1.0] {
            check_shape(&mut rng, m, k, n, zero_frac);
        }
    }
}

/// All-zero blocks (the skip path) cannot change results, including
/// whole-row and whole-block structured sparsity.
#[test]
fn packed_gemm_structured_sparsity_bit_exact() {
    let mut rng = SplitMix64::new(0xC3);
    let (m, k, n) = (2 * MR + 1, 4 * KB, 2 * NR + 3);
    let mut a = arb_codes(&mut rng, m * k, 0.0);
    // zero a full KB-aligned stripe and one full row
    for row in a.chunks_mut(k) {
        for v in &mut row[KB..3 * KB] {
            *v = 0;
        }
    }
    for v in &mut a[0..k] {
        *v = 0;
    }
    let w = arb_codes(&mut rng, k * n, 0.0);
    let packed = PackedInt8::from_row_major(&w, k, n);
    let reference = gemm_i32_ref(&a, m, k, &w, n);
    for workers in WORKER_GRID {
        assert_eq!(gemm_i32_packed(&a, m, &packed, workers), reference);
    }
}

/// Per-ISA oracle on the shapes where a SIMD kernel can go wrong: `m`
/// around the MR tile, `k` straddling the AVX2 4-step / NEON 2-step
/// vector bodies and the KB skip blocks (so the scalar tails run), `n`
/// straddling the NR panel width. `check_shape` compares every supported
/// ISA against `gemm_i32_ref` for each combination.
#[test]
fn dispatch_paths_bit_identical_on_edge_shapes() {
    let mut rng = SplitMix64::new(0xD1);
    for m in [1usize, 3, 4, 5] {
        for k in [2usize, KB - 1, KB + 1, KB + 3] {
            for n in [1usize, NR - 1, NR + 1] {
                check_shape(&mut rng, m, k, n, 0.3);
            }
        }
    }
}

/// All-zero activation blocks short-circuit through the shared live-flag
/// skip in every kernel — including rows that are entirely zero and the
/// fully-zero batch (every block skipped, output identically zero).
#[test]
fn dispatch_paths_agree_on_all_zero_blocks() {
    let mut rng = SplitMix64::new(0xD2);
    let (m, k, n) = (MR + 1, 3 * KB + 5, 2 * NR + 3);
    let mut a = arb_codes(&mut rng, m * k, 0.0);
    for row in a.chunks_mut(k) {
        for v in &mut row[KB..2 * KB] {
            *v = 0;
        }
    }
    for v in &mut a[..k] {
        *v = 0;
    }
    let w = arb_codes(&mut rng, k * n, 0.1);
    let packed = PackedInt8::from_row_major(&w, k, n);
    let reference = gemm_i32_ref(&a, m, k, &w, n);
    for isa in isas_under_test() {
        assert_eq!(gemm_i32_packed_isa(&a, m, &packed, 3, isa), reference, "isa={isa}");
        let zeros = vec![0i8; m * k];
        assert_eq!(gemm_i32_packed_isa(&zeros, m, &packed, 1, isa), vec![0i32; m * n], "{isa}");
    }
}

/// The mmapped `.cqa` panel form feeds the same kernels: pack, reload
/// the raw bytes through an Mmap view, and require every ISA to
/// reproduce the naive reference exactly over the borrowed panels.
#[test]
fn dispatch_paths_bit_identical_on_mapped_panels() {
    use std::sync::Arc;

    use crossquant::util::Mmap;

    let mut rng = SplitMix64::new(0xD3);
    let (m, k, n) = (5usize, KB + 9, 3 * NR + 5);
    let a = arb_codes(&mut rng, m * k, 0.4);
    let w = arb_codes(&mut rng, k * n, 0.1);
    let owned = PackedInt8::from_row_major(&w, k, n);
    let map = Arc::new(Mmap::from_vec(owned.raw_bytes().to_vec()));
    let mapped = PackedInt8::from_mapped(k, n, map, 0).unwrap();
    let reference = gemm_i32_ref(&a, m, k, &w, n);
    for isa in isas_under_test() {
        for workers in [1usize, 4] {
            assert_eq!(
                gemm_i32_packed_isa(&a, m, &mapped, workers, isa),
                reference,
                "mapped panels, isa={isa} workers={workers}"
            );
        }
    }
}

/// `CROSSQUANT_ISA` pins the process-wide dispatch decision — the knob
/// CI uses to re-run this whole suite on the forced-scalar path. Without
/// the override, dispatch picks the best ISA the host supports.
#[test]
fn active_isa_honors_env_override() {
    match std::env::var("CROSSQUANT_ISA") {
        Ok(v) => {
            let want: Isa = v.parse().expect("CROSSQUANT_ISA must name a known ISA");
            assert_eq!(dispatch::active(), want, "CROSSQUANT_ISA override must win");
        }
        Err(_) => assert_eq!(dispatch::active(), dispatch::best()),
    }
}

/// The fused dequant writeback applies exactly out = acc · r_i · c_j.
#[test]
fn prop_dequant_matches_reference_scaling() {
    let mut rng = SplitMix64::new(0xC4);
    for _ in 0..10 {
        let m = 1 + rng.below(3 * MR);
        let k = 1 + rng.below(KB + 9);
        let n = 1 + rng.below(3 * NR);
        let a = arb_codes(&mut rng, m * k, 0.3);
        let w = arb_codes(&mut rng, k * n, 0.1);
        let packed = PackedInt8::from_row_major(&w, k, n);
        let row_scale: Vec<f32> = (0..m).map(|_| 0.001 + rng.uniform() as f32 * 0.01).collect();
        let col_scale: Vec<f32> = (0..n).map(|_| 0.001 + rng.uniform() as f32 * 0.01).collect();
        let reference = gemm_i32_ref(&a, m, k, &w, n);
        for workers in [1usize, 4] {
            let out = gemm_dequant(&a, m, &packed, &row_scale, &col_scale, workers);
            for i in 0..m {
                for j in 0..n {
                    let expect = reference[i * n + j] as f32 * row_scale[i] * col_scale[j];
                    assert_eq!(out.get(i, j), expect, "({i},{j}) workers={workers}");
                }
            }
        }
    }
}

/// The qlinear integer forwards stay deterministic across repeated calls
/// (panel packing + parallel fold must not introduce any order
/// dependence), and the static fold built from the live batch's own
/// statistics reproduces the dynamic path bit-for-bit.
#[test]
fn qlinear_static_fold_bit_exact_with_dynamic_on_matching_stats() {
    let mut rng = SplitMix64::new(0xC5);
    let x = Matrix::randn(37, 29, 1.0, &mut rng);
    let w = Matrix::randn(29, 23, 0.1, &mut rng);
    let mut lin = QuantizedLinear::from_weight(&w, Bits::Int8);
    let dynamic = lin.forward_crossquant(&x, 0.15, Bits::Int8);
    assert_eq!(dynamic.data, lin.forward_crossquant(&x, 0.15, Bits::Int8).data);
    lin.set_scale_mode(ScaleMode::Static {
        alpha: 0.15,
        col_pow: col_pow_scales(&x.col_abs_max(), 0.15),
    });
    let st = lin.forward_crossquant_static(&x, Bits::Int8);
    assert_eq!(st.data, dynamic.data);
}

/// End-to-end deployment contract: calibrated static scales track the
/// dynamic path within 2% mean NLL on the synthetic eval (the paper-level
/// accuracy cost of replacing live column maxima with calibration).
#[test]
fn static_scale_nll_within_two_percent_of_dynamic() {
    let cfg = ModelConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        seq_len: 20,
        eval_batch: 2,
    };
    let w = synthetic_weights(cfg, 31);
    let mut qm =
        QuantizedModel::new(&w, Bits::Int8, Bits::Int8, QuantPath::CrossQuant { alpha: 0.15 })
            .unwrap();
    let eval: Vec<Vec<u32>> = (0..3)
        .map(|s| (0..20).map(|i| ((i * 7 + s) % 64) as u32).collect())
        .collect();
    let mean_nll = |qm: &QuantizedModel| -> f32 {
        let mut total = 0.0f32;
        let mut count = 0usize;
        for seq in &eval {
            let nll = qm.forward_nll(seq).unwrap();
            total += nll.iter().sum::<f32>();
            count += nll.len();
        }
        total / count as f32
    };
    let dyn_mean = mean_nll(&qm);
    let calib: Vec<Vec<u32>> = (0..8)
        .map(|s| (0..20).map(|i| ((i * 7 + s) % 64) as u32).collect())
        .collect();
    qm.calibrate_static(0.15, &calib).unwrap();
    let st_mean = mean_nll(&qm);
    let rel = (dyn_mean - st_mean).abs() / dyn_mean.max(1e-6);
    assert!(rel < 0.02, "static {st_mean} vs dynamic {dyn_mean} (rel {rel})");
}
