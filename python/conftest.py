import sys
import pathlib

# Allow `pytest python/tests/` from the repo root: make `compile.*`
# importable regardless of the working directory.
sys.path.insert(0, str(pathlib.Path(__file__).parent.resolve()))
