"""L2 correctness: model shapes, quantization-site wiring, AOT entry points."""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.common import CorpusGen, ModelConfig, param_offsets, param_size
from compile.kernels import ref
from compile.model import (
    forward_nll,
    init_params,
    layer_norm,
    lm_aq,
    lm_fp,
    lm_rk,
    make_crossquant_site,
    make_remove_kernel_site,
    unpack_params,
)

CFG = ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=24, eval_batch=2)


@pytest.fixture(scope="module")
def weights():
    return init_params(CFG, seed=1)


@pytest.fixture(scope="module")
def tokens():
    gen = CorpusGen(CFG.vocab, seed=3)
    return jnp.asarray(gen.batch(CFG.eval_batch, CFG.seq_len))


class TestParamLayout:
    def test_total_size(self, weights):
        assert weights.shape == (param_size(CFG),)

    def test_unpack_shapes(self, weights):
        p = unpack_params(CFG, weights)
        assert p["tok_emb"].shape == (CFG.vocab, CFG.d_model)
        assert p["layer0.w1"].shape == (CFG.d_model, CFG.d_ff)
        assert p["w_out"].shape == (CFG.d_model, CFG.vocab)

    def test_offsets_contiguous(self):
        offs = param_offsets(CFG)
        total = 0
        for name, (off, shape) in offs.items():
            assert off == total, name
            total += math.prod(shape)
        assert total == param_size(CFG)


class TestForward:
    def test_nll_shape_and_finite(self, weights, tokens):
        nll, kfrac, _ = forward_nll(CFG, weights, tokens)
        assert nll.shape == (CFG.eval_batch, CFG.seq_len - 1)
        assert np.all(np.isfinite(np.asarray(nll)))
        assert float(kfrac) == 0.0  # identity site

    def test_random_model_ppl_near_uniform(self, weights, tokens):
        nll, _, _ = forward_nll(CFG, weights, tokens)
        ppl = math.exp(float(jnp.mean(nll)))
        assert 0.5 * CFG.vocab < ppl < 2.0 * CFG.vocab

    def test_acts_shape(self, weights, tokens):
        _, _, acts = forward_nll(CFG, weights, tokens, collect_acts=True)
        assert acts.shape == (
            2 * CFG.n_layers + 1,
            CFG.eval_batch * CFG.seq_len,
            CFG.d_model,
        )

    def test_layer_norm_zero_mean_unit_var(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8, 32)).astype(np.float32))
        y = layer_norm(x, jnp.ones(32), jnp.zeros(32))
        np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.var(np.asarray(y), -1), 1.0, atol=1e-2)

    def test_causality(self, weights):
        """Changing a suffix token must not affect earlier NLL positions."""
        gen = CorpusGen(CFG.vocab, seed=5)
        t1 = np.asarray(gen.batch(1, CFG.seq_len))
        t2 = t1.copy()
        t2[0, -1] = (t2[0, -1] + 7) % CFG.vocab
        n1, _, _ = forward_nll(CFG, weights, jnp.asarray(t1))
        n2, _, _ = forward_nll(CFG, weights, jnp.asarray(t2))
        # all positions except the last prediction (which targets the changed
        # token) must be identical
        np.testing.assert_allclose(np.asarray(n1)[0, :-1], np.asarray(n2)[0, :-1], atol=1e-6)


class TestQuantSites:
    def test_crossquant_site_reduces_to_input_when_wide(self, weights, tokens):
        """qmax → huge: fake quant is a near-identity, NLL ≈ FP NLL."""
        fp, _, _ = forward_nll(CFG, weights, tokens)
        site = make_crossquant_site(0.15, 2.0**22, use_pallas=False)
        q, kfrac, _ = forward_nll(CFG, weights, tokens, site)
        np.testing.assert_allclose(np.asarray(q), np.asarray(fp), atol=1e-3)
        assert float(kfrac) < 1e-5

    def test_int4_worse_than_int8(self, weights, tokens):
        fp, _, _ = forward_nll(CFG, weights, tokens)
        site8 = make_crossquant_site(0.15, 127.0, use_pallas=False)
        site4 = make_crossquant_site(0.15, 7.0, use_pallas=False)
        n8, _, _ = forward_nll(CFG, weights, tokens, site8)
        n4, _, _ = forward_nll(CFG, weights, tokens, site4)
        err8 = abs(float(jnp.mean(n8) - jnp.mean(fp)))
        err4 = abs(float(jnp.mean(n4) - jnp.mean(fp)))
        assert err4 > err8

    def test_pallas_and_jnp_sites_agree(self, weights, tokens):
        site_p = make_crossquant_site(0.15, 127.0, use_pallas=True)
        site_j = make_crossquant_site(0.15, 127.0, use_pallas=False)
        np_, kp, _ = forward_nll(CFG, weights, tokens, site_p)
        nj, kj, _ = forward_nll(CFG, weights, tokens, site_j)
        np.testing.assert_allclose(np.asarray(np_), np.asarray(nj), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(kp), float(kj), atol=1e-6)

    def test_remove_kernel_theta_zero_is_identity(self, weights, tokens):
        fp, _, _ = forward_nll(CFG, weights, tokens)
        site = make_remove_kernel_site(0.0)
        n, rfrac, _ = forward_nll(CFG, weights, tokens, site)
        np.testing.assert_allclose(np.asarray(n), np.asarray(fp), atol=1e-6)
        assert float(rfrac) == 0.0

    def test_remove_kernel_fraction_monotone_in_theta(self, weights, tokens):
        fracs = []
        for theta in [0.0, 0.005, 0.02, 0.1]:
            _, rfrac, _ = forward_nll(CFG, weights, tokens, make_remove_kernel_site(theta))
            fracs.append(float(rfrac))
        assert fracs == sorted(fracs)


class TestAotEntryPoints:
    def test_lm_fp_jit(self, weights, tokens):
        (nll,) = jax.jit(lm_fp(CFG))(tokens, weights)
        assert nll.shape == (CFG.eval_batch, CFG.seq_len - 1)

    def test_lm_aq_alpha1_equals_per_token(self, weights, tokens):
        """The AOT graph with alpha=1 must reproduce per-token quantization."""
        fn = jax.jit(lm_aq(CFG, use_pallas=False))
        nll_a1, _ = fn(tokens, weights, jnp.float32(1.0), jnp.float32(127.0))

        def pt_site(x):
            b, s, f = x.shape
            x2 = x.reshape(b * s, f)
            return ref.per_token_fake_quant(x2, 127.0).reshape(b, s, f), jnp.zeros((), jnp.float32)

        nll_pt, _, _ = forward_nll(CFG, weights, tokens, pt_site)
        np.testing.assert_allclose(np.asarray(nll_a1), np.asarray(nll_pt), rtol=1e-5, atol=1e-6)

    def test_lm_rk_jit(self, weights, tokens):
        nll, rfrac = jax.jit(lm_rk(CFG))(tokens, weights, jnp.float32(0.01))
        assert nll.shape == (CFG.eval_batch, CFG.seq_len - 1)
        assert 0.0 <= float(rfrac) < 1.0


class TestCorpus:
    def test_deterministic(self):
        a = CorpusGen(64, seed=9).batch(2, 50)
        b = CorpusGen(64, seed=9).batch(2, 50)
        np.testing.assert_array_equal(a, b)

    def test_token_range(self):
        t = CorpusGen(512, seed=1).batch(4, 200)
        assert t.min() >= 0 and t.max() < 512

    def test_markov_structure_learnable(self):
        """Conditional distribution must be peaked: given prev, the modal
        next token should appear much more often than uniform."""
        gen = CorpusGen(64, seed=2)
        toks = gen.batch(1, 20000)[0]
        from collections import Counter, defaultdict

        cond = defaultdict(Counter)
        for a, b in zip(toks[:-1], toks[1:]):
            cond[int(a)][int(b)] += 1
        # average modal probability over well-populated contexts
        probs = [
            max(c.values()) / sum(c.values()) for c in cond.values() if sum(c.values()) > 50
        ]
        assert np.mean(probs) > 0.25  # ≫ 1/64
