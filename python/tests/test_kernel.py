"""L1 correctness: every Pallas kernel vs. the pure-jnp oracle (ref.py).

hypothesis sweeps shapes (including non-tile-divisible ones), alphas and
bit-widths; assert_allclose against ref.py is the core correctness signal
for the quantization hot path.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import absmax, crossquant, per_token, qmatmul, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand_matrix(rows, cols, seed, scale=1.0, outliers=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(scale=scale, size=(rows, cols)).astype(np.float32)
    if outliers:
        cols_idx = rng.choice(cols, size=min(outliers, cols), replace=False)
        x[:, cols_idx] *= 40.0
    return x


shape_st = st.tuples(st.integers(1, 300), st.integers(1, 200))
alpha_st = st.floats(0.0, 1.0, allow_nan=False)
qmax_st = st.sampled_from([7.0, 127.0])


class TestCrossQuantKernel:
    @given(shape=shape_st, alpha=alpha_st, qmax=qmax_st, seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, alpha, qmax, seed):
        x = jnp.asarray(rand_matrix(*shape, seed))
        got = crossquant.crossquant_fake_quant(x, alpha, qmax)
        want = ref.crossquant_fake_quant(x, alpha, qmax)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    @given(shape=shape_st, seed=st.integers(0, 2**16))
    def test_alpha_one_is_per_token(self, shape, seed):
        """α=1 degenerates to per-token. pow(t, 1.0) may differ from t by
        1 ulp, which can flip round() exactly at a .5 grid boundary, so we
        allow a one-grid-step (Δ_i) discrepancy per element."""
        x = jnp.asarray(rand_matrix(*shape, seed))
        got = np.asarray(crossquant.crossquant_fake_quant(x, 1.0, 127.0))
        want = np.asarray(ref.per_token_fake_quant(x, 127.0))
        delta = np.maximum(np.asarray(ref.row_abs_max(x)), ref.EPS) / 127.0
        assert np.all(np.abs(got - want) <= delta * 1.0001 + 1e-9)

    def test_with_outlier_columns(self):
        x = jnp.asarray(rand_matrix(256, 128, 7, outliers=2))
        got = crossquant.crossquant_fake_quant(x, 0.15, 127.0)
        want = ref.crossquant_fake_quant(x, 0.15, 127.0)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_zero_matrix(self):
        x = jnp.zeros((64, 64), jnp.float32)
        out = crossquant.crossquant_fake_quant(x, 0.15, 127.0)
        assert not np.any(np.isnan(out))
        np.testing.assert_array_equal(out, 0.0)

    def test_non_divisible_tile_shapes(self):
        for shape in [(1, 1), (129, 127), (5, 300), (257, 3)]:
            x = jnp.asarray(rand_matrix(*shape, 11))
            got = crossquant.crossquant_fake_quant(x, 0.15, 127.0)
            want = ref.crossquant_fake_quant(x, 0.15, 127.0)
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_values_on_integer_grid(self):
        """Dequantized output / scale must be integers within ±qmax."""
        x = jnp.asarray(rand_matrix(100, 90, 3))
        qmax = 127.0
        out = crossquant.crossquant_fake_quant(x, 0.15, qmax)
        scale = ref.cross_scale(ref.row_abs_max(x), ref.col_abs_max(x), 0.15, qmax)
        grid = np.asarray(out / scale)
        np.testing.assert_allclose(grid, np.round(grid), atol=1e-3)
        assert np.all(np.abs(grid) <= qmax + 1e-3)


class TestPerTokenKernel:
    @given(shape=shape_st, qmax=qmax_st, seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, qmax, seed):
        x = jnp.asarray(rand_matrix(*shape, seed))
        got = per_token.per_token_fake_quant(x, qmax)
        want = ref.per_token_fake_quant(x, qmax)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)

    def test_row_max_preserved(self):
        """The row absmax element quantizes to exactly ±qmax·Δ = ±t_i."""
        x = jnp.asarray(rand_matrix(64, 64, 5))
        out = np.asarray(per_token.per_token_fake_quant(x, 127.0))
        t = np.max(np.abs(np.asarray(x)), axis=1)
        t_out = np.max(np.abs(out), axis=1)
        np.testing.assert_allclose(t_out, t, rtol=1e-6)


class TestAbsMaxKernel:
    @given(shape=shape_st, seed=st.integers(0, 2**16))
    def test_matches_ref(self, shape, seed):
        x = jnp.asarray(rand_matrix(*shape, seed))
        t, c = absmax.row_col_abs_max(x)
        np.testing.assert_allclose(t, ref.row_abs_max(x), rtol=0, atol=0)
        np.testing.assert_allclose(c, ref.col_abs_max(x), rtol=0, atol=0)

    def test_multi_tile_accumulation(self):
        """Shapes spanning several grid tiles exercise the @pl.when combine."""
        x = jnp.asarray(rand_matrix(300, 300, 9))
        t, c = absmax.row_col_abs_max(x, bt=64, bi=64)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(ref.row_abs_max(x)))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(ref.col_abs_max(x)))

    def test_negative_dominated(self):
        x = -jnp.abs(jnp.asarray(rand_matrix(50, 70, 2)))
        t, c = absmax.row_col_abs_max(x)
        assert np.all(np.asarray(t) >= 0)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(ref.row_abs_max(x)))


class TestQMatmulKernel:
    @given(
        t=st.integers(1, 150),
        i=st.integers(1, 100),
        o=st.integers(1, 120),
        alpha=alpha_st,
        qmax=qmax_st,
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref(self, t, i, o, alpha, qmax, seed):
        x = jnp.asarray(rand_matrix(t, i, seed))
        w = jnp.asarray(rand_matrix(i, o, seed + 1, scale=0.1))
        got = qmatmul.qmatmul(x, w, alpha, qmax)
        want = ref.qmatmul(x, w, alpha, qmax)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_close_to_fp_matmul_int8(self):
        """INT8 quantized matmul should track the FP product closely."""
        x = jnp.asarray(rand_matrix(128, 128, 21))
        w = jnp.asarray(rand_matrix(128, 128, 22, scale=0.05))
        got = np.asarray(qmatmul.qmatmul(x, w, 0.15, 127.0))
        fp = np.asarray(x @ w)
        rel = np.linalg.norm(got - fp) / np.linalg.norm(fp)
        assert rel < 0.02, rel


class TestKernelFraction:
    """The quantization-kernel statistics that drive the paper's analysis."""

    def test_crossquant_kernel_smaller_than_per_token(self):
        """Paper §4.2: with outlier columns, K(CQ) ≪ K(Q)."""
        x = jnp.asarray(rand_matrix(512, 256, 3, outliers=3))
        kq = float(ref.per_token_kernel_fraction(x, 127.0))
        kcq = float(ref.crossquant_kernel_fraction(x, 0.15, 127.0))
        assert kcq < kq
        assert kq > 0.1  # outliers inflate the per-token kernel
        assert kcq < 0.05

    def test_kernel_matches_actual_zeros(self):
        """Definition 1: kernel fraction == fraction quantized to zero."""
        x = jnp.asarray(rand_matrix(200, 100, 4, outliers=2))
        qmax = 127.0
        out = np.asarray(ref.crossquant_fake_quant(x, 0.15, qmax))
        nonzero_in = np.asarray(x) != 0
        frac_zeroed = np.mean((out == 0) & nonzero_in)
        kfrac = float(ref.crossquant_kernel_fraction(x, 0.15, qmax))
        np.testing.assert_allclose(frac_zeroed, kfrac, atol=1e-3)

    @given(theta=st.floats(0.0, 0.5), seed=st.integers(0, 2**16))
    def test_remove_kernel_fraction(self, theta, seed):
        x = jnp.asarray(rand_matrix(100, 80, seed))
        out = np.asarray(ref.remove_kernel(x, theta))
        frac = float(ref.removed_fraction(x, theta))
        actual = np.mean((out == 0) & (np.asarray(x) != 0))
        np.testing.assert_allclose(actual, frac, atol=1e-3)
