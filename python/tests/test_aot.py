"""AOT path integrity: HLO text artifacts parse, the manifest matches the
parameter layout, and the lowered modules compute what the jitted functions
compute (executed through jax itself — the rust side re-verifies through
PJRT in rust/tests/pjrt_integration.rs).

Uses a session-scoped throwaway artifact dir with a 1-step-trained model so
the suite stays fast and independent of `make artifacts`.
"""

import json
import math
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.aot import lower_all, to_hlo_text
from compile.common import CorpusGen, ModelConfig, param_size
from compile.model import forward_nll, init_params, lm_aq, lm_fp
from compile.train import save_weights, train


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = ModelConfig()
    w, losses = train(cfg, steps=2, batch=2, log_every=100)
    save_weights(cfg, w, out, losses)
    inventory = lower_all(cfg, out)
    manifest = json.loads((out / "manifest.json").read_text())
    manifest["artifacts"] = inventory
    (out / "manifest.json").write_text(json.dumps(manifest))
    return out


class TestManifest:
    def test_layout_consistency(self, art_dir):
        manifest = json.loads((art_dir / "manifest.json").read_text())
        cfg = ModelConfig(**manifest["config"])
        assert manifest["total_params"] == param_size(cfg)
        # offsets are contiguous and ordered
        off = 0
        for p in manifest["params"]:
            assert p["offset"] == off
            assert p["size"] == int(np.prod(p["shape"]))
            off += p["size"]
        assert off == manifest["total_params"]

    def test_weights_bin_size(self, art_dir):
        manifest = json.loads((art_dir / "manifest.json").read_text())
        nbytes = (art_dir / "weights.bin").stat().st_size
        assert nbytes == 4 * manifest["total_params"]

    def test_all_artifacts_listed_and_present(self, art_dir):
        manifest = json.loads((art_dir / "manifest.json").read_text())
        names = set(manifest["artifacts"])
        assert names == {"lm_fp", "lm_aq", "lm_aq_jnp", "lm_rk", "lm_acts", "quant_ops", "qmatmul"}
        for entry in manifest["artifacts"].values():
            assert (art_dir / entry["file"]).exists()


class TestHloText:
    def test_hlo_is_parseable_text(self, art_dir):
        for f in art_dir.glob("*.hlo.txt"):
            text = f.read_text()
            assert text.startswith("HloModule"), f.name
            assert "ENTRY" in text, f.name

    def test_lowering_is_deterministic_shape(self, art_dir):
        """Re-lowering produces an HLO with the same entry signature."""
        cfg = ModelConfig()
        spec_tok = jax.ShapeDtypeStruct((cfg.eval_batch, cfg.seq_len), jnp.int32)
        spec_w = jax.ShapeDtypeStruct((param_size(cfg),), jnp.float32)
        text = to_hlo_text(jax.jit(lm_fp(cfg)).lower(spec_tok, spec_w))
        disk = (art_dir / "lm_fp.hlo.txt").read_text()
        # the parameter/result shapes in the entry computation must agree
        sig = lambda t: [l for l in t.splitlines() if "ENTRY" in l]
        assert sig(text) == sig(disk)


class TestLoweredSemantics:
    """The jitted functions the HLOs were lowered from must agree with the
    direct (unjitted) model on trained weights."""

    def test_fp_nll_matches_direct(self, art_dir):
        manifest = json.loads((art_dir / "manifest.json").read_text())
        cfg = ModelConfig(**manifest["config"])
        flat = np.fromfile(art_dir / "weights.bin", dtype="<f4")
        tokens = jnp.asarray(CorpusGen(cfg.vocab, seed=5).batch(cfg.eval_batch, cfg.seq_len))
        (nll_jit,) = jax.jit(lm_fp(cfg))(tokens, jnp.asarray(flat))
        nll_direct, _, _ = forward_nll(cfg, jnp.asarray(flat), tokens)
        np.testing.assert_allclose(np.asarray(nll_jit), np.asarray(nll_direct), rtol=1e-4, atol=1e-5)

    def test_quantized_nll_sane(self, art_dir):
        manifest = json.loads((art_dir / "manifest.json").read_text())
        cfg = ModelConfig(**manifest["config"])
        flat = jnp.asarray(np.fromfile(art_dir / "weights.bin", dtype="<f4"))
        tokens = jnp.asarray(CorpusGen(cfg.vocab, seed=6).batch(cfg.eval_batch, cfg.seq_len))
        nll, kfrac = jax.jit(lm_aq(cfg, use_pallas=True))(
            tokens, flat, jnp.float32(0.15), jnp.float32(127.0)
        )
        ppl = math.exp(float(jnp.mean(nll)))
        assert 1.0 < ppl < 10 * ModelConfig().vocab
        assert 0.0 <= float(kfrac) < 1.0


class TestTrainer:
    def test_two_steps_reduce_loss_eventually(self):
        cfg = ModelConfig(vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=64, seq_len=24, eval_batch=2)
        _, losses = train(cfg, steps=25, batch=4, log_every=100)
        assert losses[-1] < losses[0], f"{losses[0]} -> {losses[-1]}"

    def test_save_weights_roundtrip(self, tmp_path):
        cfg = ModelConfig(vocab=64, d_model=32, n_layers=1, n_heads=2, d_ff=64, seq_len=24, eval_batch=2)
        w = np.asarray(init_params(cfg, seed=3))
        save_weights(cfg, w, tmp_path, [1.0])
        back = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
        np.testing.assert_array_equal(back, w.astype("<f4"))
