"""Shared build-time definitions: model config, parameter layout, corpus.

The rust side (rust/src/model/config.rs, rust/src/corpus/) mirrors these
definitions. The parameter layout defined by `param_specs` is the single
source of truth for how the flat weight vector in artifacts/weights.bin is
sliced; aot.py serializes it into artifacts/manifest.json so rust never
hard-codes offsets.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny GPT configuration used for all experiments.

    The paper's model-size axis (OPT-1.3B..66B, LLaMA-7B..70B) is reproduced
    through *activation profiles* (outlier injection), not parameter count —
    see DESIGN.md §4.
    """

    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    seq_len: int = 96
    eval_batch: int = 8  # fixed batch of the AOT-lowered eval HLO

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat weight vector layout."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq_len, cfg.d_model)),
    ]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        specs += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
        ]
    specs += [
        ("lnf_g", (cfg.d_model,)),
        ("lnf_b", (cfg.d_model,)),
        ("w_out", (cfg.d_model, cfg.vocab)),
    ]
    return specs


def param_size(cfg: ModelConfig) -> int:
    return sum(math.prod(s) for _, s in param_specs(cfg))


def param_offsets(cfg: ModelConfig) -> dict:
    """name -> (offset, shape) into the flat weight vector."""
    out = {}
    off = 0
    for name, shape in param_specs(cfg):
        out[name] = (off, shape)
        off += math.prod(shape)
    return out


# ---------------------------------------------------------------------------
# Synthetic corpus: a Zipfian first-order Markov chain over token ids.
#
# The rust generator (rust/src/corpus/synth.rs) implements the same process
# (same Zipf exponent, same mixing map); streams need not be bit-identical
# across languages — only distribution-identical — because training data
# (python) and evaluation data (rust) are different draws anyway.
# ---------------------------------------------------------------------------

ZIPF_S = 1.4
MIX_A = 31
MIX_B = 7
MIX_C = 13


def zipf_cdf(vocab: int) -> np.ndarray:
    w = 1.0 / np.power(np.arange(1, vocab + 1, dtype=np.float64), ZIPF_S)
    return np.cumsum(w / w.sum())


class CorpusGen:
    """Deterministic synthetic corpus stream."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.cdf = zipf_cdf(vocab)
        self.rng = np.random.default_rng(seed)
        self.prev = 0

    def next_token(self) -> int:
        u = self.rng.random()
        rank = int(np.searchsorted(self.cdf, u))
        rank = min(rank, self.vocab - 1)
        tok = (self.prev * MIX_A + rank * MIX_B + MIX_C) % self.vocab
        self.prev = tok
        return tok

    def batch(self, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), dtype=np.int32)
        for b in range(batch):
            for s in range(seq):
                out[b, s] = self.next_token()
        return out
