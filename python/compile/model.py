"""L2: GPT-style language model with pluggable activation fake-quantization.

The forward pass consumes a single flat f32 weight vector (layout defined by
common.param_specs) so the AOT-lowered HLO takes exactly one weight
parameter; the rust runtime loads artifacts/weights.bin, optionally
fake-quantizes / outlier-injects / smooths it natively, and feeds it back
through the same HLO. One lowered module therefore serves every
weight-precision variant (W16/W8/W4/W4-g128) — only *activation*
quantization needs to live inside the graph, controlled by runtime scalars:

  alpha  — CrossQuant exponent (alpha = 1.0 is exactly per-token, eq. 1)
  qmax   — integer grid bound (127.0 = INT8, 7.0 = INT4)
  theta  — remove-kernel zero bound multiplier (remove-kernel variant only)

Quantization sites (the paper quantizes inputs of linear layers): the
ln1 output feeding wq/wk/wv, the attention context feeding wo, the ln2
output feeding w1, the GELU output feeding w2, and the lnf output feeding
w_out. Attention-internal matmuls (QKᵀ, PV) stay FP, as in SmoothQuant-O1
and the paper's fake-quant protocol.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, param_offsets, param_specs
from .kernels import crossquant as cq_kernel
from .kernels import ref


def unpack_params(cfg: ModelConfig, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Slice the flat weight vector into named tensors (static offsets)."""
    out = {}
    for name, (off, shape) in param_offsets(cfg).items():
        size = math.prod(shape)
        out[name] = flat[off : off + size].reshape(shape)
    return out


def layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def causal_attention(cfg: ModelConfig, q, k, v) -> jnp.ndarray:
    b, s, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return ctx.transpose(0, 2, 1, 3).reshape(b, s, d)


# ---------------------------------------------------------------------------
# Quantization site plumbing
# ---------------------------------------------------------------------------

QuantFn = Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]]
"""Maps a (B,S,F) activation to (possibly-quantized activation, kernel count)."""


def identity_site(x):
    return x, jnp.zeros((), jnp.float32)


def make_crossquant_site(alpha, qmax, use_pallas: bool) -> QuantFn:
    """Fake-quantize a 3D activation token-wise (rows = tokens)."""

    def site(x):
        b, s, f = x.shape
        x2 = x.reshape(b * s, f)
        if use_pallas:
            out = cq_kernel.crossquant_fake_quant(x2, alpha, qmax)
        else:
            out = ref.crossquant_fake_quant(x2, alpha, qmax)
        kcount = ref.kernel_fraction(
            x2, ref.cross_scale(ref.row_abs_max(x2), ref.col_abs_max(x2), alpha, qmax)
        ) * (b * s * f)
        return out.reshape(b, s, f), kcount

    return site


def make_remove_kernel_site(theta) -> QuantFn:
    """The paper's Remove-Kernel ablation: zero |x| < θ·t_i, keep the rest FP."""

    def site(x):
        b, s, f = x.shape
        x2 = x.reshape(b * s, f)
        out = ref.remove_kernel(x2, theta)
        rcount = ref.removed_fraction(x2, theta) * (b * s * f)
        return out.reshape(b, s, f), rcount

    return site


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def forward_nll(
    cfg: ModelConfig,
    flat_w: jnp.ndarray,
    tokens: jnp.ndarray,
    site: QuantFn = identity_site,
    collect_acts: bool = False,
):
    """Forward pass returning per-position NLL.

    Returns (nll[B, S-1], kernel_fraction scalar, acts or None). `acts` is
    the stack of pre-linear LN outputs [(2·L+1), B·S, D] consumed by the
    rust analysis engine for Figure 4.
    """
    p = unpack_params(cfg, flat_w)
    b, s = tokens.shape
    x = jnp.take(p["tok_emb"], tokens, axis=0) + p["pos_emb"][None, :s, :]

    total_kernel = jnp.zeros((), jnp.float32)
    total_elems = 0.0
    acts: List[jnp.ndarray] = []

    state = {"kernel": total_kernel, "elems": total_elems}

    def quant(h):
        out, kcount = site(h)
        state["kernel"] = state["kernel"] + kcount
        state["elems"] += float(h.size)
        return out

    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        h = layer_norm(x, p[pre + "ln1_g"], p[pre + "ln1_b"])
        if collect_acts:
            acts.append(h.reshape(b * s, cfg.d_model))
        hq = quant(h)
        q = hq @ p[pre + "wq"]
        k = hq @ p[pre + "wk"]
        v = hq @ p[pre + "wv"]
        ctx = causal_attention(cfg, q, k, v)
        ctx = quant(ctx)
        x = x + ctx @ p[pre + "wo"]

        h = layer_norm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
        if collect_acts:
            acts.append(h.reshape(b * s, cfg.d_model))
        hq = quant(h)
        hh = jax.nn.gelu(hq @ p[pre + "w1"])
        hh = quant(hh)
        x = x + hh @ p[pre + "w2"]

    h = layer_norm(x, p["lnf_g"], p["lnf_b"])
    if collect_acts:
        acts.append(h.reshape(b * s, cfg.d_model))
    hq = quant(h)
    logits = hq @ p["w_out"]

    logp = jax.nn.log_softmax(logits, axis=-1)
    targets = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp[:, :-1, :], targets[..., None], axis=-1)[..., 0]
    kfrac = jnp.asarray(
        state["kernel"] / state["elems"] if state["elems"] > 0 else 0.0, jnp.float32
    )
    act_stack = jnp.stack(acts) if collect_acts else None
    return nll, kfrac, act_stack


# ---------------------------------------------------------------------------
# The functions aot.py lowers (fixed signatures = HLO parameter lists)
# ---------------------------------------------------------------------------


def lm_fp(cfg: ModelConfig):
    def fn(tokens, flat_w):
        nll, _, _ = forward_nll(cfg, flat_w, tokens)
        return (nll,)

    return fn


def lm_aq(cfg: ModelConfig, use_pallas: bool = True):
    """Activation-quantized forward. alpha=1 → per-token; qmax selects bits."""

    def fn(tokens, flat_w, alpha, qmax):
        site = make_crossquant_site(alpha, qmax, use_pallas)
        nll, kfrac, _ = forward_nll(cfg, flat_w, tokens, site)
        return (nll, kfrac)

    return fn


def lm_rk(cfg: ModelConfig):
    def fn(tokens, flat_w, theta):
        site = make_remove_kernel_site(theta)
        nll, rfrac, _ = forward_nll(cfg, flat_w, tokens, site)
        return (nll, rfrac)

    return fn


def lm_acts(cfg: ModelConfig):
    def fn(tokens, flat_w):
        _, _, acts = forward_nll(cfg, flat_w, tokens, collect_acts=True)
        return (acts,)

    return fn


def init_params(cfg: ModelConfig, seed: int = 0) -> jnp.ndarray:
    """GPT-2-style init, flattened in param_specs order."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            t = jnp.ones(shape, jnp.float32)
        elif name.endswith("_b"):
            t = jnp.zeros(shape, jnp.float32)
        elif name.endswith("w2") or name.endswith("wo"):
            std = 0.02 / math.sqrt(2.0 * cfg.n_layers)
            t = jax.random.normal(sub, shape, jnp.float32) * std
        else:
            t = jax.random.normal(sub, shape, jnp.float32) * 0.02
        chunks.append(t.reshape(-1))
    return jnp.concatenate(chunks)
