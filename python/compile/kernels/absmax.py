"""L1 Pallas kernel: fused row + column absolute-maximum reduction.

Produces both the per-token vector t (T,1) and the per-channel vector c
(1,I) in a single pass over X — the CrossQuant prologue. On TPU this is the
memory-bound half of the method (one HBM read of X, two tiny writes), so
fusing the two reductions halves prologue traffic vs. calling jnp.max twice.

The kernel walks the grid row-major and accumulates partial maxima into the
output refs; Pallas guarantees sequential grid iteration on TPU, and
interpret mode preserves those semantics on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 128
DEFAULT_BI = 128


def _absmax_tile(x_ref, t_ref, c_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    a = jnp.abs(x_ref[...])
    row = jnp.max(a, axis=1, keepdims=True)  # (BT, 1)
    col = jnp.max(a, axis=0, keepdims=True)  # (1, BI)

    # First tile of each row/column strip initialises; later tiles combine.
    @pl.when(j == 0)
    def _init_t():
        t_ref[...] = row

    @pl.when(j != 0)
    def _acc_t():
        t_ref[...] = jnp.maximum(t_ref[...], row)

    @pl.when(i == 0)
    def _init_c():
        c_ref[...] = col

    @pl.when(i != 0)
    def _acc_c():
        c_ref[...] = jnp.maximum(c_ref[...], col)


@functools.partial(jax.jit, static_argnames=("bt", "bi"))
def _absmax_tiled(x, bt: int, bi: int):
    tt, ii = x.shape
    grid = (tt // bt, ii // bi)
    return pl.pallas_call(
        _absmax_tile,
        grid=grid,
        in_specs=[pl.BlockSpec((bt, bi), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bi), lambda i, j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tt, 1), x.dtype),
            jax.ShapeDtypeStruct((1, ii), x.dtype),
        ],
        interpret=True,
    )(x)


def row_col_abs_max(x, bt: int = DEFAULT_BT, bi: int = DEFAULT_BI):
    """Fused (t, c) = (max|X_i,:|, max|X_:,j|) over a (T, I) matrix."""
    tt, ii = x.shape
    bt = min(bt, max(tt, 1))
    bi = min(bi, max(ii, 1))
    pt = (-tt) % bt
    pi = (-ii) % bi
    xp = jnp.pad(x, ((0, pt), (0, pi)))  # zero padding cannot raise an absmax
    t, c = _absmax_tiled(xp, bt, bi)
    return t[:tt, :], c[:, :ii]
