"""L1 Pallas kernel: per-token fake quantization (baseline, eq. 1).

Structurally a strict subset of the CrossQuant kernel: only the row absmax
vector is streamed alongside the tile. Kept as its own kernel (rather than
CrossQuant with α=1) so the baseline costs exactly what the paper's
baseline costs — no pow() in the scale path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BT = 128
DEFAULT_BI = 128


def _per_token_tile(x_ref, t_ref, qmax_ref, o_ref):
    x = x_ref[...]
    qmax = qmax_ref[0, 0]
    scale = jnp.maximum(t_ref[...], ref.EPS) / qmax  # (BT, 1)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    o_ref[...] = q * scale


@functools.partial(jax.jit, static_argnames=("bt", "bi"))
def _per_token_tiled(x, t, qmax, bt: int, bi: int):
    tt, ii = x.shape
    grid = (tt // bt, ii // bi)
    return pl.pallas_call(
        _per_token_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bi), lambda i, j: (i, j)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bi), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((tt, ii), x.dtype),
        interpret=True,
    )(x, t, qmax)


def per_token_fake_quant(x, qmax, bt: int = DEFAULT_BT, bi: int = DEFAULT_BI):
    """Per-token fake quantization of a (T, I) activation matrix."""
    tt, ii = x.shape
    bt = min(bt, max(tt, 1))
    bi = min(bi, max(ii, 1))
    t = ref.row_abs_max(x)
    pt = (-tt) % bt
    pi = (-ii) % bi
    xp = jnp.pad(x, ((0, pt), (0, pi)))
    tp = jnp.pad(t, ((0, pt), (0, 0)), constant_values=1.0)
    q2 = jnp.asarray(qmax, x.dtype).reshape(1, 1)
    out = _per_token_tiled(xp, tp, q2, bt, bi)
    return out[:tt, :ii]
