"""L1 Pallas kernel: integer W8A8/W4A4-style matmul with CrossQuant scales.

Computes Y = dequant( quant_CQ(X) @ quant_perchannel(W) ) using the
factorization from ref.qmatmul: the column part of the CrossQuant scale
(c_k^(1−α)) folds into the weight rows so the inner loop is a plain
integer-grid matmul that maps onto the MXU (bf16/int8 systolic tiles on
real TPU; f32 exact-integer arithmetic under interpret mode).

Grid: (T/BT, O/BO); the contraction dimension I is kept whole per tile
(I ≤ a few K for the models here, comfortably inside VMEM: the X tile is
BT·I·4 bytes, the W tile I·BO·4 bytes — see DESIGN.md §Perf for the
footprint table).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BT = 128
DEFAULT_BO = 128


def _qmatmul_tile(xq_ref, wf_ref, t_ref, ws_ref, qmax_ref, o_ref):
    """One (BT, BO) output tile.

    xq: (BT, I) integer-grid activations,
    wf: (I, BO) weight integer grid pre-scaled by c^(1−α),
    t:  (BT, 1) t_i^α, ws: (1, BO) per-channel weight scale.
    """
    qmax = qmax_ref[0, 0]
    acc = jnp.dot(xq_ref[...], wf_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc * (t_ref[...] / qmax) * ws_ref[...]


@functools.partial(jax.jit, static_argnames=("bt", "bo"))
def _qmatmul_tiled(xq, wf, ta, ws, qmax, bt: int, bo: int):
    tt, ii = xq.shape
    oo = wf.shape[1]
    grid = (tt // bt, oo // bo)
    return pl.pallas_call(
        _qmatmul_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, ii), lambda i, j: (i, 0)),
            pl.BlockSpec((ii, bo), lambda i, j: (0, j)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bo), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((tt, oo), jnp.float32),
        interpret=True,
    )(xq, wf, ta, ws, qmax)


def qmatmul(x, w, alpha, qmax, bt: int = DEFAULT_BT, bo: int = DEFAULT_BO):
    """Integer quantized matmul: CrossQuant activations × per-channel weights.

    Matches ref.qmatmul exactly (same factorization, same EPS guards).
    """
    tt, ii = x.shape
    oo = w.shape[1]
    bt = min(bt, max(tt, 1))
    bo = min(bo, max(oo, 1))

    t = jnp.maximum(ref.row_abs_max(x), ref.EPS)
    c = jnp.maximum(ref.col_abs_max(x), ref.EPS)
    act_scale = (t**alpha) * (c ** (1.0 - alpha)) / qmax
    xq = jnp.clip(jnp.round(x / act_scale), -qmax, qmax)
    ws = jnp.maximum(ref.col_abs_max(w), ref.EPS) / qmax
    wq = jnp.clip(jnp.round(w / ws), -qmax, qmax)
    wf = wq * (c.reshape(-1, 1) ** (1.0 - alpha))
    ta = t**alpha

    pt = (-tt) % bt
    po = (-oo) % bo
    xqp = jnp.pad(xq, ((0, pt), (0, 0)))
    wfp = jnp.pad(wf, ((0, 0), (0, po)))
    tap = jnp.pad(ta, ((0, pt), (0, 0)), constant_values=1.0)
    wsp = jnp.pad(ws, ((0, 0), (0, po)), constant_values=1.0)
    q2 = jnp.asarray(qmax, jnp.float32).reshape(1, 1)
    out = _qmatmul_tiled(xqp, wfp, tap, wsp, q2, bt, bo)
    return out[:tt, :oo]
