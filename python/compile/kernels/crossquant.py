"""L1 Pallas kernel: CrossQuant fake quantization (the paper's hot spot).

TPU-oriented structure (see DESIGN.md §Hardware-Adaptation):
  * the activation is processed in (BT, BI) VMEM-resident tiles via
    BlockSpec; extra HBM traffic beyond X itself is only the t (T,1) and
    c (1,I) absmax vectors — O(T+I), matching the paper's storage claim;
  * the cross scale t_i^α·c_j^(1−α) is formed in-register per tile and is
    never materialised as a T×I matrix;
  * α and qmax arrive as (1,1) SMEM-style operands broadcast to every tile.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO and runs on any backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BT = 128
DEFAULT_BI = 128


def _crossquant_tile(x_ref, t_ref, c_ref, alpha_ref, qmax_ref, o_ref):
    """One (BT, BI) tile: o = clip(round(x / Δ̃), ±qmax) · Δ̃."""
    x = x_ref[...]
    alpha = alpha_ref[0, 0]
    qmax = qmax_ref[0, 0]
    t = jnp.maximum(t_ref[...], ref.EPS)  # (BT, 1)
    c = jnp.maximum(c_ref[...], ref.EPS)  # (1, BI)
    scale = (t**alpha) * (c ** (1.0 - alpha)) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    o_ref[...] = q * scale


@functools.partial(jax.jit, static_argnames=("bt", "bi"))
def _crossquant_tiled(x, t, c, alpha, qmax, bt: int, bi: int):
    tt, ii = x.shape
    grid = (tt // bt, ii // bi)
    return pl.pallas_call(
        _crossquant_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bi), lambda i, j: (i, j)),
            pl.BlockSpec((bt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bi), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bi), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((tt, ii), x.dtype),
        interpret=True,
    )(x, t, c, alpha, qmax)


def crossquant_fake_quant(x, alpha, qmax, bt: int = DEFAULT_BT, bi: int = DEFAULT_BI):
    """CrossQuant fake quantization of a (T, I) activation matrix.

    Handles arbitrary shapes by padding up to tile multiples (padded cells
    are zero and are sliced away; padding cannot perturb t/c because the
    absmax vectors are computed on the *unpadded* matrix and padded rows /
    columns receive scale contributions only from their own t/c entries,
    which are never read back).

    alpha / qmax may be python floats or traced scalars — both lower into
    the same HLO, so the AOT artifact exposes them as runtime inputs.
    """
    tt, ii = x.shape
    bt = min(bt, max(tt, 1))
    bi = min(bi, max(ii, 1))
    t = ref.row_abs_max(x)
    c = ref.col_abs_max(x)
    pt = (-tt) % bt
    pi = (-ii) % bi
    xp = jnp.pad(x, ((0, pt), (0, pi)))
    tp = jnp.pad(t, ((0, pt), (0, 0)), constant_values=1.0)
    cp = jnp.pad(c, ((0, 0), (0, pi)), constant_values=1.0)
    a2 = jnp.asarray(alpha, x.dtype).reshape(1, 1)
    q2 = jnp.asarray(qmax, x.dtype).reshape(1, 1)
    out = _crossquant_tiled(xp, tp, cp, a2, q2, bt, bi)
    return out[:tt, :ii]
