"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: pytest asserts each Pallas kernel
(interpret mode) against these functions across hypothesis-generated shapes,
dtypes and alphas. They also serve as the L2 fallback implementation when a
shape does not tile cleanly.

All fake-quant functions follow the paper's formulation:

  Per-token (eq. 1):  Q(X_ij) = round(X_ij / Δ_ij),  Δ_ij = t_i / qmax
  CrossQuant (eq. 5): CQ(X_ij) = round(X_ij / Δ̃_ij), Δ̃_ij = t_i^α c_j^(1−α) / qmax

with t_i = max|X_i,:|, c_j = max|X_:,j| and qmax = 2^(N−1) − 1. "Fake quant"
means we immediately dequantize (multiply the integer grid value back by the
scale), which is the paper's own evaluation protocol (Appendix B.1).
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-9


def row_abs_max(x: jnp.ndarray) -> jnp.ndarray:
    """t: per-row absolute maximum, shape (T, 1)."""
    return jnp.max(jnp.abs(x), axis=-1, keepdims=True)


def col_abs_max(x: jnp.ndarray) -> jnp.ndarray:
    """c: per-column absolute maximum, shape (1, I)."""
    return jnp.max(jnp.abs(x), axis=-2, keepdims=True)


def cross_scale(t: jnp.ndarray, c: jnp.ndarray, alpha, qmax) -> jnp.ndarray:
    """Δ̃_ij = t_i^α · c_j^(1−α) / qmax, broadcast to (T, I).

    alpha = 1 recovers per-token quantization exactly. Zero rows/columns are
    guarded with EPS so that an all-zero input quantizes to all-zero output
    instead of NaN.
    """
    t = jnp.maximum(t, EPS)
    c = jnp.maximum(c, EPS)
    return (t**alpha) * (c ** (1.0 - alpha)) / qmax


def crossquant_fake_quant(x: jnp.ndarray, alpha, qmax) -> jnp.ndarray:
    """CrossQuant fake quantization (quantize + dequantize), eq. (5)."""
    scale = cross_scale(row_abs_max(x), col_abs_max(x), alpha, qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q * scale


def per_token_fake_quant(x: jnp.ndarray, qmax) -> jnp.ndarray:
    """Per-token fake quantization, eq. (1)."""
    scale = jnp.maximum(row_abs_max(x), EPS) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q * scale


def per_channel_fake_quant(w: jnp.ndarray, qmax) -> jnp.ndarray:
    """Per-(output-)channel weight fake quantization, eq. (2).

    w has shape (I, O); the quantization unit is one output channel
    (a column of w).
    """
    scale = jnp.maximum(col_abs_max(w), EPS) / qmax
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    return q * scale


def groupwise_fake_quant(w: jnp.ndarray, qmax, group: int) -> jnp.ndarray:
    """Group-wise weight fake quantization (reshape to (I·O/g, g) first)."""
    shape = w.shape
    flat = w.reshape(-1, group)
    scale = jnp.maximum(row_abs_max(flat), EPS) / qmax
    q = jnp.clip(jnp.round(flat / scale), -qmax, qmax)
    return (q * scale).reshape(shape)


def crossquant_weight_fake_quant(w: jnp.ndarray, alpha_w, qmax) -> jnp.ndarray:
    """CrossQuant applied to weights (Appendix B.1: OPT-66B W4A4 etc.)."""
    return crossquant_fake_quant(w, alpha_w, qmax)


def kernel_mask(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Membership mask of the quantization kernel K(Q): |x| < 0.5·Δ (eq. 4).

    Only non-zero elements count: a structural zero quantizes to zero but is
    not information lost (the paper's Definition 1 concerns elements whose
    value is destroyed by quantization).
    """
    return (jnp.abs(x) < 0.5 * scale) & (x != 0.0)


def kernel_fraction(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Fraction of elements of x that fall in the quantization kernel."""
    return jnp.mean(kernel_mask(x, scale).astype(jnp.float32))


def crossquant_kernel_fraction(x: jnp.ndarray, alpha, qmax) -> jnp.ndarray:
    return kernel_fraction(x, cross_scale(row_abs_max(x), col_abs_max(x), alpha, qmax))


def per_token_kernel_fraction(x: jnp.ndarray, qmax) -> jnp.ndarray:
    return kernel_fraction(x, jnp.maximum(row_abs_max(x), EPS) / qmax)


def remove_kernel(x: jnp.ndarray, theta) -> jnp.ndarray:
    """The paper's "Remove Kernel" ablation: zero elements with |x| < θ·t_i
    WITHOUT quantizing the rest (Figures 1, 6, 7, 9)."""
    bound = theta * row_abs_max(x)
    return jnp.where(jnp.abs(x) < bound, 0.0, x)


def removed_fraction(x: jnp.ndarray, theta) -> jnp.ndarray:
    bound = theta * row_abs_max(x)
    return jnp.mean(((jnp.abs(x) < bound) & (x != 0.0)).astype(jnp.float32))


def qmatmul(x: jnp.ndarray, w: jnp.ndarray, alpha, qmax) -> jnp.ndarray:
    """True-integer W8A8-style matmul reference.

    Activations are CrossQuant-quantized to the integer grid, weights
    per-channel quantized, the matmul accumulates over the integer grids,
    and the result is dequantized.

    With CrossQuant the activation scale is per-element (t_i^α·c_j^(1−α)),
    which does not factor out of the matmul as a rank-1 outer product the
    way per-token scales do; the integer-kernel formulation therefore folds
    the column part c_k^(1−α) into the weight rows — the TPU-friendly
    factorization described in DESIGN.md §Hardware-Adaptation:

        Y_ij = (t_i^α / qmax) · s_j · Σ_k xq_ik · [c_k^(1−α) · wq_kj]
    """
    t = jnp.maximum(row_abs_max(x), EPS)
    c = jnp.maximum(col_abs_max(x), EPS)
    act_scale = (t**alpha) * (c ** (1.0 - alpha)) / qmax
    xq = jnp.clip(jnp.round(x / act_scale), -qmax, qmax)  # integer grid
    w_scale = jnp.maximum(col_abs_max(w), EPS) / qmax  # (1, O)
    wq = jnp.clip(jnp.round(w / w_scale), -qmax, qmax)
    acc = xq @ (wq * (c.reshape(-1, 1) ** (1.0 - alpha)))
    return acc * (t**alpha / qmax) * w_scale
