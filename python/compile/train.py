"""Build-time trainer: fit the tiny GPT on the synthetic corpus.

Runs once inside `make artifacts` (skipped if artifacts/weights.bin already
exists). Pure jax Adam — a few hundred steps on CPU take a couple of
minutes and reach well below the unigram entropy floor, which is all the
quantization experiments need (they compare schemes on the *same* model).

Python never runs at request time; the resulting weights.bin + manifest.json
are loaded by rust/src/model/weights.rs.
"""

from __future__ import annotations

import argparse
import json
import math
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from .common import CorpusGen, ModelConfig, param_specs
from .model import forward_nll, init_params


def loss_fn(cfg: ModelConfig, flat_w, tokens):
    nll, _, _ = forward_nll(cfg, flat_w, tokens)
    return jnp.mean(nll)


def make_update(cfg: ModelConfig, lr: float = 1e-3, b1=0.9, b2=0.99, eps=1e-8):
    grad_fn = jax.value_and_grad(lambda w, t: loss_fn(cfg, w, t))

    @jax.jit
    def update(w, m, v, step, tokens):
        loss, g = grad_fn(w, tokens)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1**step)
        vhat = v / (1 - b2**step)
        w = w - lr * mhat / (jnp.sqrt(vhat) + eps)
        return w, m, v, loss

    return update


def train(
    cfg: ModelConfig,
    steps: int = 400,
    batch: int = 8,
    seed: int = 0,
    log_every: int = 50,
) -> np.ndarray:
    gen = CorpusGen(cfg.vocab, seed=seed)
    w = init_params(cfg, seed=seed)
    m = jnp.zeros_like(w)
    v = jnp.zeros_like(w)
    update = make_update(cfg)
    t0 = time.time()
    losses = []
    for step in range(1, steps + 1):
        tokens = jnp.asarray(gen.batch(batch, cfg.seq_len))
        w, m, v, loss = update(w, m, v, float(step), tokens)
        losses.append(float(loss))
        if step % log_every == 0 or step == 1:
            print(
                f"step {step:4d}  loss {float(loss):.4f}  ppl {math.exp(float(loss)):.2f}"
                f"  ({time.time() - t0:.1f}s)",
                flush=True,
            )
    return np.asarray(w), losses


def save_weights(cfg: ModelConfig, w: np.ndarray, out_dir: Path, losses) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    w.astype("<f4").tofile(out_dir / "weights.bin")
    table = []
    off = 0
    for name, shape in param_specs(cfg):
        size = int(np.prod(shape))
        table.append({"name": name, "shape": list(shape), "offset": off, "size": size})
        off += size
    manifest = {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "eval_batch": cfg.eval_batch,
        },
        "params": table,
        "total_params": off,
        "train": {
            "final_loss": losses[-1],
            "final_ppl": math.exp(losses[-1]),
            "steps": len(losses),
        },
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out_dir/'weights.bin'} ({off} params) + manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = ModelConfig()
    w, losses = train(cfg, steps=args.steps, batch=args.batch, seed=args.seed)
    save_weights(cfg, w, Path(args.out), losses)


if __name__ == "__main__":
    main()
