"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

HLO text — NOT `lowered.compiler_ir("hlo").serialize()` — is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which the
xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser on the rust side reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Artifacts (all consumed by rust/src/runtime/):
  weights.bin / manifest.json   trained flat weights + layout (train.py)
  lm_fp.hlo.txt        (tokens i32[B,S], w f32[P]) -> (nll f32[B,S-1],)
  lm_aq.hlo.txt        (tokens, w, alpha f32[], qmax f32[]) -> (nll, kfrac)
                       activation fake-quant via the Pallas CrossQuant kernel
  lm_aq_jnp.hlo.txt    same signature, pure-jnp quant (XLA-fused fast path)
  lm_rk.hlo.txt        (tokens, w, theta f32[]) -> (nll, removed_frac)
  lm_acts.hlo.txt      (tokens, w) -> (acts f32[2L+1, B·S, D],)
  quant_ops.hlo.txt    (x f32[QT,QI], alpha, qmax) -> (xq, kfrac, t, c)
                       standalone Pallas CrossQuant + fused absmax
  qmatmul.hlo.txt      (x f32[QT,QI], wm f32[QI,QO], alpha, qmax) -> (y,)
                       standalone Pallas integer matmul

`make artifacts` is incremental: the Makefile only reruns this when the
python sources change; rerunning with an existing weights.bin reuses it
(pass --retrain to discard).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .common import ModelConfig
from .kernels import absmax as absmax_kernel
from .kernels import crossquant as cq_kernel
from .kernels import qmatmul as qmatmul_kernel
from .kernels import ref
from .model import lm_acts, lm_aq, lm_fp, lm_rk
from .train import save_weights, train

# Standalone quant-op artifact shapes (fixed; rust pads/slices around them).
QT, QI, QO = 512, 256, 128
F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def quant_ops_fn(x, alpha, qmax):
    t, c = absmax_kernel.row_col_abs_max(x)
    xq = cq_kernel.crossquant_fake_quant(x, alpha, qmax)
    kfrac = ref.kernel_fraction(x, ref.cross_scale(t, c, alpha, qmax))
    return (xq, kfrac, t.reshape(-1), c.reshape(-1))


def qmatmul_fn(x, w, alpha, qmax):
    return (qmatmul_kernel.qmatmul(x, w, alpha, qmax),)


def lower_all(cfg: ModelConfig, out_dir: Path) -> dict:
    b, s, p = cfg.eval_batch, cfg.seq_len, None
    from .common import param_size

    p = param_size(cfg)
    tok = spec((b, s), I32)
    w = spec((p,), F32)
    scalar = spec((), F32)

    entries = {
        "lm_fp": (lm_fp(cfg), [tok, w]),
        "lm_aq": (lm_aq(cfg, use_pallas=True), [tok, w, scalar, scalar]),
        "lm_aq_jnp": (lm_aq(cfg, use_pallas=False), [tok, w, scalar, scalar]),
        "lm_rk": (lm_rk(cfg), [tok, w, scalar]),
        "lm_acts": (lm_acts(cfg), [tok, w]),
        "quant_ops": (quant_ops_fn, [spec((QT, QI), F32), scalar, scalar]),
        "qmatmul": (qmatmul_fn, [spec((QT, QI), F32), spec((QI, QO), F32), scalar, scalar]),
    }
    inventory = {}
    for name, (fn, in_specs) in entries.items():
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        inventory[name] = {
            "file": path.name,
            "inputs": [[list(s.shape), str(s.dtype)] for s in in_specs],
        }
        print(f"lowered {name:12s} -> {path.name} ({len(text)} chars)")
    return inventory


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--retrain", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    cfg = ModelConfig()

    if args.retrain or not (out_dir / "weights.bin").exists():
        weights, losses = train(cfg, steps=args.steps)
        save_weights(cfg, weights, out_dir, losses)
    else:
        print("weights.bin exists — reusing (pass --retrain to discard)")

    inventory = lower_all(cfg, out_dir)

    manifest_path = out_dir / "manifest.json"
    manifest = json.loads(manifest_path.read_text())
    manifest["artifacts"] = inventory
    manifest["quant_ops_shape"] = {"t": QT, "i": QI, "o": QO}
    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"updated {manifest_path}")


if __name__ == "__main__":
    main()
