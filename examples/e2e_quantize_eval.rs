//! END-TO-END driver (EXPERIMENTS.md §E2E): proves all three layers
//! compose on a real small workload.
//!
//! 1. `make artifacts` trained the tiny GPT (L2, JAX) on the synthetic
//!    corpus and AOT-lowered the forward passes — with the Pallas
//!    CrossQuant kernel (L1) inlined — to HLO text.
//! 2. This binary (L3, rust) loads weights.bin, prepares three weight
//!    variants (W16 / W8 per-channel / W4-g128), registers them with the
//!    PJRT coordinator, and streams batched evaluation requests through
//!    the compiled executables — Python nowhere on the path.
//! 3. It reports the paper's headline metric: perplexity under per-token
//!    vs CrossQuant activation quantization (and the measured
//!    quantization-kernel fraction), plus coordinator latency metrics.
//!
//!     make artifacts && cargo run --release --example e2e_quantize_eval

use std::time::Instant;

use crossquant::activations::FamilyProfile;
use crossquant::coordinator::scheduler::CoordinatorConfig;
use crossquant::coordinator::{ActScheme, EvalCoordinator};
use crossquant::corpus::{CorpusGen, CorpusKind};
use crossquant::model::quantized::{inject_profile, quantize_weights, WeightScheme};
use crossquant::quant::Bits;
use crossquant::runtime::{ArtifactStore, Runtime};

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::discover(None)?;
    store.validate()?;
    let base = store.load_weights()?;
    let cfg = base.config;
    println!(
        "loaded model: {} params, vocab {}, d_model {}, {} layers (trained ppl {:.2})",
        base.manifest.total_params,
        cfg.vocab,
        cfg.d_model,
        cfg.n_layers,
        base.manifest.train.as_ref().map(|t| t.final_ppl).unwrap_or(f64::NAN),
    );

    // The e2e scenario of the paper: an OPT-6.7B-like model (systematic
    // activation outliers) quantized W8A8 with per-token vs CrossQuant.
    let profile = FamilyProfile::by_name("opt-6.7b").expect("profile");
    let mut injected = base.clone();
    inject_profile(&mut injected, &profile)?;

    let mut w8 = injected.clone();
    quantize_weights(&mut w8, WeightScheme::PerChannel(Bits::Int8))?;
    let mut w4g = injected.clone();
    quantize_weights(&mut w4g, WeightScheme::GroupWise(Bits::Int4, 128))?;

    let coordinator = EvalCoordinator::start(
        store,
        cfg,
        vec![
            ("w16".into(), injected.flat.clone()),
            ("w8".into(), w8.flat),
            ("w4g128".into(), w4g.flat),
        ],
        CoordinatorConfig::default(),
    );

    // evaluation stream: 64 sequences from the Wiki2-like corpus
    let mut gen = CorpusGen::with_kind(cfg.vocab, 0xE2E, CorpusKind::Wiki2);
    let seqs: Vec<Vec<u32>> = (0..64).map(|_| gen.sequence(cfg.seq_len)).collect();
    println!("\nevaluating 64 sequences × {} tokens through PJRT (profile {}):\n", cfg.seq_len, profile.name);

    let cells: Vec<(&str, ActScheme, &str)> = vec![
        ("FP16            W16A16", ActScheme::Fp, "w16"),
        ("Per-token       W8A8  ", ActScheme::CrossQuant { alpha: 1.0, qmax: 127.0 }, "w8"),
        ("CrossQuant      W8A8  ", ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 }, "w8"),
        ("Per-token       W4A8  ", ActScheme::CrossQuant { alpha: 1.0, qmax: 127.0 }, "w4g128"),
        ("CrossQuant      W4A8  ", ActScheme::CrossQuant { alpha: 0.15, qmax: 127.0 }, "w4g128"),
        ("Remove-Kernel   W8A16*", ActScheme::RemoveKernel { theta: 0.5 / 127.0 }, "w8"),
    ];

    println!("{:26} {:>10} {:>14} {:>12}", "method", "ppl", "kernel/removed", "wall");
    for (label, scheme, wset) in cells {
        let t0 = Instant::now();
        let (mean_nll, aux) = coordinator.evaluate_stream(seqs.clone(), scheme, wset)?;
        println!(
            "{:26} {:>10.3} {:>13.2}% {:>11.1?}",
            label,
            mean_nll.exp(),
            aux * 100.0,
            t0.elapsed()
        );
    }

    println!("\ncoordinator metrics: {}", coordinator.metrics.summary());
    println!("\nExpected shape (paper Fig. 1 / Tab. 2): per-token W8A8 degrades sharply on");
    println!("the outlier profile while CrossQuant stays at the FP16 level; Remove-Kernel");
    println!("(zeroing exactly the per-token kernel, quantizing nothing) tracks per-token —");
    println!("the kernel IS the loss mechanism.");
    Ok(())
}
