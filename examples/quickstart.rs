//! Quickstart: quantize one synthetic OPT-like activation matrix with every
//! scheme in the library and print the quantization-kernel report — the
//! paper's core diagnostic — plus reconstruction error and packed sizes.
//!
//!     cargo run --release --example quickstart

use crossquant::activations::{ActivationGen, FamilyProfile};
use crossquant::analysis::kernel::KernelReport;
use crossquant::quant::{
    clipping::ClippedPerToken, crossquant::CrossQuant, pack::PackedMatrix, per_token::PerToken,
    relative_error, ActQuantizer, Bits,
};

fn main() {
    // 1. synthesize activations with OPT-66B-like outlier channels
    let profile = FamilyProfile::by_name("opt-66b").expect("profile");
    let x = ActivationGen::new(profile.clone(), 42).matrix(512, 256);
    println!(
        "activation matrix 512×256, profile {} ({} outlier channels at {}×)\n",
        profile.name, profile.outlier_channels, profile.outlier_scale
    );

    // 2. every activation quantizer
    let quants: Vec<Box<dyn ActQuantizer>> = vec![
        Box::new(PerToken::new(Bits::Int8)),
        Box::new(PerToken::new(Bits::Int4)),
        Box::new(CrossQuant::new(0.15, Bits::Int8)),
        Box::new(CrossQuant::new(0.15, Bits::Int4)),
        Box::new(CrossQuant::new(0.45, Bits::Int8)),
        Box::new(ClippedPerToken::new(Bits::Int8, 0.5)),
    ];
    println!("{:34} {:>10} {:>12} {:>12}", "scheme", "kernel", "rel. error", "compression");
    for q in &quants {
        let report = KernelReport::compute(&x, q.as_ref());
        let err = relative_error(&x, &q.fake_quant(&x));
        let packed = PackedMatrix::pack(&x, q.as_ref());
        println!(
            "{:34} {:>9.2}% {:>12.5} {:>11.2}x",
            report.scheme,
            report.fraction * 100.0,
            err,
            packed.compression_ratio()
        );
    }

    // 3. the paper's headline comparison, spelled out
    let pt = KernelReport::compute(&x, &PerToken::new(Bits::Int8));
    let cq = KernelReport::compute(&x, &CrossQuant::new(0.15, Bits::Int8));
    println!(
        "\nPer-token INT8 quantizes {:.1}% of elements to zero; CrossQuant α=0.15 only {:.1}%.",
        pt.fraction * 100.0,
        cq.fraction * 100.0
    );
    println!("That shrinkage of the quantization kernel is the paper's entire mechanism.");
}
