//! Figure-8 driver: sweep CrossQuant's α from 0.05 to 1.0 and watch
//! (a) OPT-6.7B-profile accuracy on the Lambada-like task at W8A8 and
//! (b) LLaMA2-13B-profile Wiki2 perplexity at W4A8-g128 respond. As α → 1
//! CrossQuant degenerates to per-token quantization and quality collapses
//! on the OPT profile.
//!
//!     cargo run --release --example alpha_sweep
//!
//! Uses the trained artifacts if present, otherwise synthetic weights
//! (pass CROSSQUANT_ARTIFACTS to point elsewhere).

use crossquant::exp::{self, common::ExpOpts};
use crossquant::model::weights::synthetic_weights;
use crossquant::model::ModelConfig;
use crossquant::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    let base = match ArtifactStore::discover(None).and_then(|s| s.load_weights()) {
        Ok(w) => {
            println!("using trained weights from artifacts/");
            w
        }
        Err(e) => {
            println!("no artifacts ({e}); falling back to synthetic weights");
            synthetic_weights(ModelConfig::default_build(), 7)
        }
    };
    let opts = ExpOpts { eval_sequences: 8, task_instances: 30, calib_sequences: 2, seed: 0xA1FA };
    let table = exp::fig8::run(&base, &opts)?;
    table.print();
    println!("\n(α = 1.0 is exactly per-token quantization — the rightmost column");
    println!(" is the baseline every other column improves on.)");
    Ok(())
}
