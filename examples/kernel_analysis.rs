//! Figure-3-style worked example: a small activation matrix with one
//! outlier column, its per-token and CrossQuant quantization kernels
//! marked element by element, and the zero-bound math printed.
//!
//!     cargo run --release --example kernel_analysis

use crossquant::analysis::kernel_mask;
use crossquant::quant::{crossquant::CrossQuant, per_token::PerToken, ActQuantizer, Bits};
use crossquant::tensor::{Matrix, SplitMix64};

fn render(x: &Matrix, mask: &[bool]) -> String {
    let mut out = String::new();
    for i in 0..x.rows {
        for j in 0..x.cols {
            let v = x.get(i, j);
            let marker = if mask[i * x.cols + j] { "*" } else { " " };
            out.push_str(&format!("{v:8.3}{marker}"));
        }
        out.push('\n');
    }
    out
}

fn main() {
    // 4×6 sample with an outlier column (column 0), like the paper's Fig. 3
    let mut rng = SplitMix64::new(3);
    let mut x = Matrix::randn(4, 6, 0.12, &mut rng);
    for i in 0..4 {
        x.set(i, 0, 18.0 + i as f32);
    }

    let pt = PerToken::new(Bits::Int8);
    let cq = CrossQuant::new(0.15, Bits::Int8);

    println!("sample activation matrix X (column 0 is an outlier channel):\n");
    let pt_field = pt.delta_field(&x);
    let cq_field = cq.delta_field(&x);
    let pt_mask = kernel_mask(&x, &pt_field);
    let cq_mask = kernel_mask(&x, &cq_field);

    println!("Per-token INT8 — elements in K(Q) marked with '*':");
    println!("{}", render(&x, &pt_mask));
    println!("CrossQuant α=0.15 INT8 — elements in K(CQ) marked with '*':");
    println!("{}", render(&x, &cq_mask));

    println!("zero bounds for row 0 (B = 0.5·Δ):");
    for j in 0..x.cols {
        println!(
            "  col {j}: per-token B = {:.5}   crossquant B̃ = {:.5}   ({})",
            pt_field.zero_bound(0, j),
            cq_field.zero_bound(0, j),
            if cq_field.zero_bound(0, j) < pt_field.zero_bound(0, j) {
                "B̃ < B — kernel shrinks"
            } else {
                "B̃ ≥ B — paper's Case II"
            }
        );
    }

    let k_pt = pt_mask.iter().filter(|&&b| b).count();
    let k_cq = cq_mask.iter().filter(|&&b| b).count();
    println!("\n|K(Q)| = {k_pt} / 24   |K(CQ)| = {k_cq} / 24");
}
